//! The paper's 22-dataset benchmark suite (Table 1), backed by exact or
//! surrogate generators (DESIGN.md §4).
//!
//! Every [`DatasetSpec`] carries the paper's (ℓ, C, γ) plus the reported
//! SV / BSV counts so experiment reports can print paper-vs-measured side
//! by side. `generate(len, seed)` draws a dataset of any size — the
//! default experiment scale caps ℓ so the suite finishes in CI time, while
//! `--full` restores the paper's sizes.

use super::dataset::Dataset;
use super::synth::{banana, chessboard, ringnorm, surrogate, twonorm, waveform, SurrogateSpec};

/// Which generator backs a dataset.
#[derive(Debug, Clone)]
pub enum Generator {
    /// Glasmachers & Igel's chess-board problem on a `board × board` grid.
    Chessboard {
        /// Squares per side.
        board: usize,
    },
    /// Breiman's twonorm (two offset Gaussians).
    Twonorm,
    /// Breiman's ringnorm (nested Gaussians of different scale).
    Ringnorm,
    /// Breiman's waveform (noisy convex wave combinations).
    Waveform,
    /// Two noisy interleaved crescents.
    Banana,
    /// Tuned surrogate for a UCI/Rätsch dataset (DESIGN.md §4).
    Surrogate(SurrogateSpec),
}

/// One row of the paper's Table 1 plus its generator.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// Dataset name as printed in Table 1 / `pasmo datasets`.
    pub name: &'static str,
    /// ℓ in the paper.
    pub paper_len: usize,
    /// Regularization parameter C from Table 1.
    pub c: f64,
    /// RBF kernel width γ from Table 1.
    pub gamma: f64,
    /// Support vectors reported in Table 1 (rounded means).
    pub paper_sv: usize,
    /// Bounded support vectors reported in Table 1.
    pub paper_bsv: usize,
    /// The generator standing in for the real dataset.
    pub generator: Generator,
}

impl DatasetSpec {
    /// Draw `len` examples (deterministically in `seed`).
    pub fn generate(&self, len: usize, seed: u64) -> Dataset {
        match &self.generator {
            Generator::Chessboard { board } => chessboard(len, *board, seed),
            Generator::Twonorm => twonorm(len, seed),
            Generator::Ringnorm => ringnorm(len, seed),
            Generator::Waveform => waveform(len, seed),
            Generator::Banana => banana(len, seed),
            Generator::Surrogate(spec) => surrogate(len, spec, seed),
        }
    }

    /// Experiment size: paper ℓ scaled by `scale`, floored at 64.
    pub fn scaled_len(&self, scale: f64) -> usize {
        ((self.paper_len as f64 * scale).round() as usize).max(64)
    }
}

fn sur(
    dim: usize,
    clusters: usize,
    separation: f64,
    label_noise: f64,
    positive_fraction: f64,
    binary_fraction: f64,
) -> Generator {
    Generator::Surrogate(SurrogateSpec {
        dim,
        clusters,
        separation,
        label_noise,
        positive_fraction,
        binary_fraction,
    })
}

/// The full 22-dataset suite in the paper's Table 1 order.
///
/// Surrogate knobs: `label_noise` is tuned to the paper's BSV fraction
/// (noisy labels inside the class-overlap region end up at the box bound);
/// `separation` to the SV fraction; `binary_fraction` marks the game /
/// categorical datasets.
pub fn suite() -> Vec<DatasetSpec> {
    use Generator::*;
    vec![
        DatasetSpec { name: "banana", paper_len: 5300, c: 100.0, gamma: 0.25, paper_sv: 1223, paper_bsv: 1199, generator: Banana },
        DatasetSpec { name: "breast-cancer", paper_len: 277, c: 0.6, gamma: 0.1, paper_sv: 178, paper_bsv: 131, generator: sur(9, 2, 1.2, 0.22, 0.29, 0.0) },
        DatasetSpec { name: "diabetis", paper_len: 768, c: 0.5, gamma: 0.05, paper_sv: 445, paper_bsv: 414, generator: sur(8, 2, 1.1, 0.25, 0.35, 0.0) },
        DatasetSpec { name: "flare-solar", paper_len: 1066, c: 1.5, gamma: 0.1, paper_sv: 744, paper_bsv: 709, generator: sur(9, 2, 0.8, 0.32, 0.55, 0.4) },
        DatasetSpec { name: "german", paper_len: 1000, c: 1.0, gamma: 0.05, paper_sv: 620, paper_bsv: 426, generator: sur(20, 3, 1.2, 0.21, 0.30, 0.3) },
        DatasetSpec { name: "heart", paper_len: 270, c: 1.0, gamma: 0.005, paper_sv: 158, paper_bsv: 149, generator: sur(13, 2, 1.4, 0.24, 0.44, 0.2) },
        DatasetSpec { name: "image", paper_len: 2310, c: 100.0, gamma: 0.1, paper_sv: 301, paper_bsv: 84, generator: sur(18, 4, 2.8, 0.015, 0.57, 0.0) },
        DatasetSpec { name: "ringnorm", paper_len: 7400, c: 2.0, gamma: 0.1, paper_sv: 625, paper_bsv: 86, generator: Ringnorm },
        DatasetSpec { name: "splice", paper_len: 3175, c: 10.0, gamma: 0.01, paper_sv: 1426, paper_bsv: 7, generator: sur(60, 3, 2.0, 0.0, 0.52, 0.8) },
        DatasetSpec { name: "thyroid", paper_len: 215, c: 500.0, gamma: 0.05, paper_sv: 17, paper_bsv: 3, generator: sur(5, 1, 4.5, 0.005, 0.3, 0.0) },
        DatasetSpec { name: "titanic", paper_len: 2201, c: 1000.0, gamma: 0.1, paper_sv: 934, paper_bsv: 915, generator: sur(3, 2, 0.9, 0.3, 0.32, 0.7) },
        DatasetSpec { name: "twonorm", paper_len: 7400, c: 0.5, gamma: 0.02, paper_sv: 734, paper_bsv: 662, generator: Twonorm },
        DatasetSpec { name: "waveform", paper_len: 5000, c: 1.0, gamma: 0.05, paper_sv: 1262, paper_bsv: 980, generator: Waveform },
        DatasetSpec { name: "chess-board-1000", paper_len: 1000, c: 1e6, gamma: 0.5, paper_sv: 41, paper_bsv: 3, generator: Chessboard { board: 4 } },
        DatasetSpec { name: "chess-board-10000", paper_len: 10_000, c: 1e6, gamma: 0.5, paper_sv: 129, paper_bsv: 84, generator: Chessboard { board: 4 } },
        DatasetSpec { name: "chess-board-100000", paper_len: 100_000, c: 1e6, gamma: 0.5, paper_sv: 556, paper_bsv: 504, generator: Chessboard { board: 4 } },
        DatasetSpec { name: "connect-4", paper_len: 61_108, c: 4.5, gamma: 0.2, paper_sv: 13_485, paper_bsv: 5994, generator: sur(42, 6, 1.8, 0.07, 0.66, 1.0) },
        DatasetSpec { name: "king-rook-vs-king", paper_len: 28_056, c: 10.0, gamma: 0.5, paper_sv: 5815, paper_bsv: 206, generator: sur(6, 8, 2.2, 0.004, 0.5, 0.0) },
        DatasetSpec { name: "tic-tac-toe", paper_len: 958, c: 200.0, gamma: 0.02, paper_sv: 104, paper_bsv: 0, generator: sur(9, 3, 3.0, 0.0, 0.65, 1.0) },
        DatasetSpec { name: "internet-ads", paper_len: 2358, c: 10.0, gamma: 0.03, paper_sv: 1350, paper_bsv: 6, generator: sur(200, 3, 2.2, 0.0, 0.14, 0.9) },
        DatasetSpec { name: "ionosphere", paper_len: 351, c: 3.0, gamma: 0.4, paper_sv: 190, paper_bsv: 8, generator: sur(34, 2, 2.4, 0.01, 0.64, 0.0) },
        DatasetSpec { name: "spam-database", paper_len: 4601, c: 10.0, gamma: 0.005, paper_sv: 1982, paper_bsv: 583, generator: sur(57, 3, 1.6, 0.06, 0.39, 0.2) },
    ]
}

/// Look a dataset up by name.
pub fn find(name: &str) -> Option<DatasetSpec> {
    suite().into_iter().find(|d| d.name == name)
}

/// The fast sub-suite used by default in benches: every generator family,
/// bounded sizes.
pub fn fast_suite_names() -> Vec<&'static str> {
    vec![
        "banana",
        "breast-cancer",
        "diabetis",
        "heart",
        "thyroid",
        "titanic",
        "twonorm",
        "ringnorm",
        "waveform",
        "tic-tac-toe",
        "ionosphere",
        "chess-board-1000",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_all_22_paper_rows() {
        let s = suite();
        assert_eq!(s.len(), 22);
        let names: Vec<&str> = s.iter().map(|d| d.name).collect();
        for want in [
            "banana", "splice", "chess-board-100000", "connect-4", "spam-database",
        ] {
            assert!(names.contains(&want), "{want} missing");
        }
    }

    #[test]
    fn find_and_generate() {
        let spec = find("chess-board-1000").unwrap();
        assert_eq!(spec.paper_len, 1000);
        assert_eq!(spec.c, 1e6);
        let ds = spec.generate(128, 7);
        assert_eq!(ds.len(), 128);
        assert_eq!(ds.dim(), 2);
    }

    #[test]
    fn every_spec_generates_nonempty_balancedish_data() {
        for spec in suite() {
            let ds = spec.generate(256, 42);
            assert_eq!(ds.len(), 256, "{}", spec.name);
            assert!(ds.dim() >= 2, "{}", spec.name);
            let (p, n) = ds.class_counts();
            assert!(p > 10 && n > 10, "{}: degenerate classes {p}/{n}", spec.name);
        }
    }

    #[test]
    fn scaled_len_floors() {
        let spec = find("thyroid").unwrap();
        assert_eq!(spec.scaled_len(1.0), 215);
        assert_eq!(spec.scaled_len(0.001), 64);
    }

    #[test]
    fn fast_suite_is_subset() {
        for name in fast_suite_names() {
            assert!(find(name).is_some(), "{name}");
        }
    }
}
