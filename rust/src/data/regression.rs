//! Dense regression dataset + synthetic generators (substrate for the
//! ε-SVR extension, `svm::svr`).

use crate::util::prng::Pcg;

/// A dense regression dataset: rows of f32 features with f64 targets.
#[derive(Debug, Clone, PartialEq)]
pub struct RegressionDataset {
    dim: usize,
    features: Vec<f32>,
    targets: Vec<f64>,
}

impl RegressionDataset {
    /// Empty dataset with a fixed feature dimension.
    pub fn with_dim(dim: usize) -> RegressionDataset {
        assert!(dim > 0);
        RegressionDataset { dim, features: Vec::new(), targets: Vec::new() }
    }

    /// Append one example.
    pub fn push(&mut self, x: &[f32], y: f64) {
        assert_eq!(x.len(), self.dim);
        self.features.extend_from_slice(x);
        self.targets.push(y);
    }

    /// Number of examples ℓ.
    pub fn len(&self) -> usize {
        self.targets.len()
    }

    /// Is the dataset empty?
    pub fn is_empty(&self) -> bool {
        self.targets.is_empty()
    }

    /// Feature dimension d.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Feature row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Regression target of example `i`.
    #[inline]
    pub fn target(&self, i: usize) -> f64 {
        self.targets[i]
    }

    /// All targets, in example order.
    pub fn targets(&self) -> &[f64] {
        &self.targets
    }

    /// Raw row-major feature buffer (the batch-scoring input shape).
    pub fn features(&self) -> &[f32] {
        &self.features
    }
}

/// The classic `sinc` regression benchmark: `y = sin(x)/x + noise` on
/// `[-10, 10]` (1-D).
pub fn sinc(n: usize, noise_sd: f64, seed: u64) -> RegressionDataset {
    let mut rng = Pcg::new(seed);
    let mut ds = RegressionDataset::with_dim(1);
    for _ in 0..n {
        let x = rng.range(-10.0, 10.0);
        let clean = if x.abs() < 1e-9 { 1.0 } else { x.sin() / x };
        ds.push(&[x as f32], clean + rng.normal() * noise_sd);
    }
    ds
}

/// A noisy linear target in `d` dimensions: `y = w·x + b + noise`.
pub fn linear_target(n: usize, d: usize, noise_sd: f64, seed: u64) -> RegressionDataset {
    let mut rng = Pcg::new(seed);
    let w: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let b = rng.normal();
    let mut ds = RegressionDataset::with_dim(d);
    let mut row = vec![0f32; d];
    for _ in 0..n {
        let mut y = b;
        for (k, v) in row.iter_mut().enumerate() {
            *v = rng.normal() as f32;
            y += w[k] * *v as f64;
        }
        ds.push(&row, y + rng.normal() * noise_sd);
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sinc_targets_follow_the_function() {
        let ds = sinc(200, 0.0, 1);
        for i in 0..ds.len() {
            let x = ds.row(i)[0] as f64;
            let want = if x.abs() < 1e-9 { 1.0 } else { x.sin() / x };
            assert!((ds.target(i) - want).abs() < 1e-6);
        }
    }

    #[test]
    fn noise_increases_target_variance() {
        let clean = sinc(2000, 0.0, 2);
        let noisy = sinc(2000, 0.5, 2);
        let var = |ds: &RegressionDataset| {
            let m = ds.targets().iter().sum::<f64>() / ds.len() as f64;
            ds.targets().iter().map(|t| (t - m).powi(2)).sum::<f64>() / ds.len() as f64
        };
        assert!(var(&noisy) > var(&clean) + 0.1);
    }

    #[test]
    fn linear_target_shapes() {
        let ds = linear_target(50, 3, 0.1, 3);
        assert_eq!(ds.len(), 50);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.row(10).len(), 3);
    }
}
