//! Feature scaling to `[-1, 1]` or zero-mean/unit-variance.
//!
//! The paper's benchmarks (following Rätsch et al.) are normalized before
//! training; RBF-kernel SVMs are scale-sensitive, so generators and
//! LIBSVM-loaded data go through one of these before solving.
//!
//! Scaling shifts exact zeros to nonzero values, so it inherently
//! destroys sparsity: fitting reads rows through densifying views (both
//! backends accepted) and [`Scaler::apply`] always produces a
//! dense-storage dataset.

use super::dataset::Dataset;

/// Per-feature affine transform `x' = (x - shift) * factor`.
#[derive(Debug, Clone)]
pub struct Scaler {
    shift: Vec<f32>,
    factor: Vec<f32>,
}

impl Scaler {
    /// Fit a min-max scaler mapping each feature to `[-1, 1]`.
    /// Constant features map to 0.
    pub fn fit_minmax(ds: &Dataset) -> Scaler {
        let d = ds.dim();
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        let mut buf = vec![0f32; d];
        for i in 0..ds.len() {
            ds.row_ref(i).densify_into(&mut buf);
            for (k, &v) in buf.iter().enumerate() {
                lo[k] = lo[k].min(v);
                hi[k] = hi[k].max(v);
            }
        }
        let mut shift = vec![0f32; d];
        let mut factor = vec![0f32; d];
        for k in 0..d {
            if hi[k] > lo[k] {
                shift[k] = (hi[k] + lo[k]) / 2.0;
                factor[k] = 2.0 / (hi[k] - lo[k]);
            } // else constant: shift=lo, factor=0 -> maps to 0
            if hi[k] == lo[k] {
                shift[k] = lo[k];
            }
        }
        Scaler { shift, factor }
    }

    /// Fit a standardizer (zero mean, unit variance; constant features -> 0).
    pub fn fit_standard(ds: &Dataset) -> Scaler {
        let d = ds.dim();
        let n = ds.len().max(1) as f64;
        let mut mean = vec![0f64; d];
        let mut buf = vec![0f32; d];
        for i in 0..ds.len() {
            ds.row_ref(i).densify_into(&mut buf);
            for (k, &v) in buf.iter().enumerate() {
                mean[k] += v as f64;
            }
        }
        mean.iter_mut().for_each(|m| *m /= n);
        let mut var = vec![0f64; d];
        for i in 0..ds.len() {
            ds.row_ref(i).densify_into(&mut buf);
            for (k, &v) in buf.iter().enumerate() {
                let dlt = v as f64 - mean[k];
                var[k] += dlt * dlt;
            }
        }
        let shift: Vec<f32> = mean.iter().map(|&m| m as f32).collect();
        let factor: Vec<f32> = var
            .iter()
            .map(|&v| {
                let sd = (v / n).sqrt();
                if sd > 1e-12 {
                    (1.0 / sd) as f32
                } else {
                    0.0
                }
            })
            .collect();
        Scaler { shift, factor }
    }

    /// Apply to a dataset, producing a new dense-storage dataset
    /// (scaled zeros are generally nonzero, so sparsity does not
    /// survive the transform).
    pub fn apply(&self, ds: &Dataset) -> Dataset {
        let mut out = Dataset::with_dim(ds.dim());
        let mut buf = vec![0f32; ds.dim()];
        let mut row = vec![0f32; ds.dim()];
        for i in 0..ds.len() {
            ds.row_ref(i).densify_into(&mut buf);
            for (k, &v) in buf.iter().enumerate() {
                row[k] = (v - self.shift[k]) * self.factor[k];
            }
            out.push(&row, ds.label(i));
        }
        out
    }

    /// Apply to a single feature vector in place (for predict-time queries).
    pub fn apply_row(&self, x: &mut [f32]) {
        for (k, v) in x.iter_mut().enumerate() {
            *v = (*v - self.shift[k]) * self.factor[k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(
            2,
            vec![0.0, 10.0, 2.0, 10.0, 4.0, 10.0, 1.0, 10.0],
            vec![1, -1, 1, -1],
        )
    }

    #[test]
    fn minmax_maps_to_unit_interval_and_kills_constants() {
        let ds = toy();
        let s = Scaler::fit_minmax(&ds);
        let t = s.apply(&ds);
        for i in 0..t.len() {
            assert!(t.row(i)[0] >= -1.0 && t.row(i)[0] <= 1.0);
            assert_eq!(t.row(i)[1], 0.0); // constant feature
        }
        // extremes hit the interval ends
        assert_eq!(t.row(0)[0], -1.0);
        assert_eq!(t.row(2)[0], 1.0);
    }

    #[test]
    fn standard_gives_zero_mean_unit_var() {
        let ds = toy();
        let s = Scaler::fit_standard(&ds);
        let t = s.apply(&ds);
        let n = t.len() as f64;
        let mean: f64 = (0..t.len()).map(|i| t.row(i)[0] as f64).sum::<f64>() / n;
        let var: f64 = (0..t.len())
            .map(|i| (t.row(i)[0] as f64 - mean).powi(2))
            .sum::<f64>()
            / n;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-5);
    }

    #[test]
    fn apply_row_matches_apply() {
        let ds = toy();
        let s = Scaler::fit_minmax(&ds);
        let t = s.apply(&ds);
        let mut row = ds.row(3).to_vec();
        s.apply_row(&mut row);
        assert_eq!(row.as_slice(), t.row(3));
    }
}
