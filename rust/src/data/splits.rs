//! Permutations and k-fold cross-validation splits.
//!
//! The paper's Table 2 averages over 100 random permutations of each
//! dataset (the permutation changes LIBSVM's first-iteration tie-breaking
//! and hence the whole optimization path); grid search uses k-fold CV.

use crate::util::prng::Pcg;

/// `count` random permutations of `0..n`, deterministically derived from
/// `seed` (permutation p uses stream `seed ⊕ p`).
pub fn permutations(n: usize, count: usize, seed: u64) -> Vec<Vec<usize>> {
    (0..count)
        .map(|p| Pcg::new(seed ^ (p as u64).wrapping_mul(0xA24BAED4963EE407)).permutation(n))
        .collect()
}

/// k-fold split: returns `k` (train_idx, test_idx) pairs covering `0..n`,
/// shuffled by `seed`. Folds differ in size by at most one.
pub fn kfold(n: usize, k: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2 && k <= n, "need 2 <= k <= n");
    let order = Pcg::new(seed).permutation(n);
    let mut folds: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (i, &idx) in order.iter().enumerate() {
        folds[i % k].push(idx);
    }
    (0..k)
        .map(|f| {
            let test = folds[f].clone();
            let train: Vec<usize> = (0..k)
                .filter(|&g| g != f)
                .flat_map(|g| folds[g].iter().copied())
                .collect();
            (train, test)
        })
        .collect()
}

/// Stratified train/test split preserving class balance.
pub fn train_test_split(
    labels: &[i8],
    test_fraction: f64,
    seed: u64,
) -> (Vec<usize>, Vec<usize>) {
    assert!((0.0..1.0).contains(&test_fraction));
    let mut rng = Pcg::new(seed);
    let mut train = Vec::new();
    let mut test = Vec::new();
    for class in [1i8, -1] {
        let mut idx: Vec<usize> = labels
            .iter()
            .enumerate()
            .filter(|(_, &y)| y == class)
            .map(|(i, _)| i)
            .collect();
        rng.shuffle(&mut idx);
        let ntest = (idx.len() as f64 * test_fraction).round() as usize;
        test.extend_from_slice(&idx[..ntest]);
        train.extend_from_slice(&idx[ntest..]);
    }
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permutations_are_valid_and_distinct() {
        let ps = permutations(50, 5, 7);
        assert_eq!(ps.len(), 5);
        for p in &ps {
            let mut sorted = p.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        }
        assert_ne!(ps[0], ps[1]);
        // deterministic
        assert_eq!(ps, permutations(50, 5, 7));
    }

    #[test]
    fn kfold_partitions_everything() {
        let folds = kfold(23, 5, 3);
        assert_eq!(folds.len(), 5);
        let mut seen = vec![0usize; 23];
        for (train, test) in &folds {
            assert_eq!(train.len() + test.len(), 23);
            for &i in test {
                seen[i] += 1;
            }
            // train and test disjoint
            for &i in test {
                assert!(!train.contains(&i));
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "each index in exactly one test fold");
    }

    #[test]
    fn stratified_split_preserves_balance() {
        let labels: Vec<i8> = (0..100).map(|i| if i < 30 { 1 } else { -1 }).collect();
        let (train, test) = train_test_split(&labels, 0.2, 11);
        assert_eq!(train.len() + test.len(), 100);
        let tpos = test.iter().filter(|&&i| labels[i] == 1).count();
        assert_eq!(tpos, 6); // 20% of 30
        assert_eq!(test.len(), 20);
    }

    #[test]
    #[should_panic]
    fn kfold_rejects_k_larger_than_n() {
        kfold(3, 5, 0);
    }
}
