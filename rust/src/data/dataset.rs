//! Dense binary-classification dataset.
//!
//! SMO's hot path is full-row kernel evaluation, so features are stored
//! dense row-major f32 (the layout both the native SIMD-friendly path and
//! the PJRT artifacts consume). Labels are ±1.

/// A dense binary-classification dataset: `len` rows of `dim` f32 features
/// plus ±1 labels.
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    dim: usize,
    /// Row-major `[len, dim]`.
    features: Vec<f32>,
    labels: Vec<i8>,
}

impl Dataset {
    /// Build from row-major features and ±1 labels.
    pub fn new(dim: usize, features: Vec<f32>, labels: Vec<i8>) -> Dataset {
        assert!(dim > 0, "dim must be positive");
        assert_eq!(features.len(), labels.len() * dim, "features/labels mismatch");
        assert!(
            labels.iter().all(|&y| y == 1 || y == -1),
            "labels must be +/-1"
        );
        Dataset { dim, features, labels }
    }

    /// Empty dataset with a fixed feature dimension.
    pub fn with_dim(dim: usize) -> Dataset {
        Dataset { dim, features: Vec::new(), labels: Vec::new() }
    }

    /// Append one example.
    pub fn push(&mut self, x: &[f32], y: i8) {
        assert_eq!(x.len(), self.dim);
        assert!(y == 1 || y == -1);
        self.features.extend_from_slice(x);
        self.labels.push(y);
    }

    /// Number of examples ℓ.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Is the dataset empty?
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Feature dimension d.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Feature row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Label of example `i` (±1).
    #[inline]
    pub fn label(&self, i: usize) -> i8 {
        self.labels[i]
    }

    /// All labels.
    pub fn labels(&self) -> &[i8] {
        &self.labels
    }

    /// Raw row-major feature buffer.
    pub fn features(&self) -> &[f32] {
        &self.features
    }

    /// Counts of (positive, negative) labels.
    pub fn class_counts(&self) -> (usize, usize) {
        let pos = self.labels.iter().filter(|&&y| y == 1).count();
        (pos, self.labels.len() - pos)
    }

    /// New dataset with rows gathered by `idx` (`idx[i]` = source row).
    /// One up-front reservation and a bulk row copy per index — the
    /// already-validated source rows need no per-row shape/label asserts,
    /// which matters on the CV-split path where every fold of every grid
    /// point re-materializes its subsets.
    fn gather(&self, idx: &[usize]) -> Dataset {
        let mut features = Vec::with_capacity(idx.len() * self.dim);
        let mut labels = Vec::with_capacity(idx.len());
        for &src in idx {
            features.extend_from_slice(self.row(src));
            labels.push(self.labels[src]);
        }
        Dataset { dim: self.dim, features, labels }
    }

    /// New dataset with rows reordered by `perm` (perm[i] = source index).
    pub fn permuted(&self, perm: &[usize]) -> Dataset {
        assert_eq!(perm.len(), self.len());
        self.gather(perm)
    }

    /// Subset by index list (used by CV splits).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        self.gather(idx)
    }

    /// Squared Euclidean distance between rows i and j (f64 accumulate).
    #[inline]
    pub fn sqdist(&self, i: usize, j: usize) -> f64 {
        let (a, b) = (self.row(i), self.row(j));
        let mut s = 0.0f64;
        for k in 0..self.dim {
            let d = (a[k] - b[k]) as f64;
            s += d * d;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(2, vec![0.0, 0.0, 1.0, 0.0, 0.0, 2.0], vec![1, -1, 1])
    }

    #[test]
    fn basic_accessors() {
        let d = toy();
        assert_eq!(d.len(), 3);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.row(1), &[1.0, 0.0]);
        assert_eq!(d.label(1), -1);
        assert_eq!(d.class_counts(), (2, 1));
    }

    #[test]
    fn sqdist_matches_hand_computation() {
        let d = toy();
        assert_eq!(d.sqdist(0, 1), 1.0);
        assert_eq!(d.sqdist(0, 2), 4.0);
        assert_eq!(d.sqdist(1, 2), 5.0);
        assert_eq!(d.sqdist(2, 2), 0.0);
    }

    #[test]
    fn permuted_reorders_rows_and_labels() {
        let d = toy();
        let p = d.permuted(&[2, 0, 1]);
        assert_eq!(p.row(0), d.row(2));
        assert_eq!(p.label(0), d.label(2));
        assert_eq!(p.row(2), d.row(1));
        assert_eq!(p.len(), 3);
    }

    #[test]
    fn subset_picks_rows() {
        let d = toy();
        let s = d.subset(&[0, 2]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.row(1), d.row(2));
    }

    #[test]
    fn subset_of_permuted_equals_composed_indexing() {
        let d = Dataset::new(
            2,
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0],
            vec![1, -1, 1, -1, 1],
        );
        let perm = [4usize, 2, 0, 3, 1];
        let idx = [1usize, 1, 4, 0];
        let two_step = d.permuted(&perm).subset(&idx);
        let composed: Vec<usize> = idx.iter().map(|&i| perm[i]).collect();
        let direct = d.subset(&composed);
        assert_eq!(two_step, direct);
        // repeats are allowed in subsets
        assert_eq!(two_step.row(0), two_step.row(1));
        assert_eq!(two_step.row(0), d.row(2));
    }

    #[test]
    #[should_panic(expected = "labels must be")]
    fn rejects_bad_labels() {
        Dataset::new(1, vec![0.0], vec![0]);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn rejects_shape_mismatch() {
        Dataset::new(2, vec![0.0; 5], vec![1, -1]);
    }
}
