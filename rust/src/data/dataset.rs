//! Binary-classification dataset over the [`Features`] substrate.
//!
//! SMO's hot path is full-row kernel evaluation, so features live in a
//! [`Features`] matrix — dense row-major f32 (the layout both the
//! native SIMD-friendly path and the PJRT artifacts consume) or CSR
//! sparse for the high-dimensional low-density regime. Labels are ±1.
//! The kernel/scorer layers consume rows through [`Dataset::row_ref`],
//! which is backend-agnostic; [`Dataset::row`] and
//! [`Dataset::features`] remain as the dense-only fast accessors for
//! paths that require the row-major layout (they panic on sparse
//! storage rather than silently densifying).

use super::features::{Features, Row};

/// A binary-classification dataset: `len` rows of `dim` features (dense
/// or CSR sparse storage) plus ±1 labels.
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    features: Features,
    labels: Vec<i8>,
}

impl Dataset {
    /// Build from row-major dense features and ±1 labels.
    pub fn new(dim: usize, features: Vec<f32>, labels: Vec<i8>) -> Dataset {
        assert!(dim > 0, "dim must be positive");
        assert_eq!(features.len(), labels.len() * dim, "features/labels mismatch");
        assert!(
            labels.iter().all(|&y| y == 1 || y == -1),
            "labels must be +/-1"
        );
        Dataset { features: Features::dense(dim, features), labels }
    }

    /// Build from a [`Features`] matrix (either backend) and ±1 labels.
    pub fn from_features(features: Features, labels: Vec<i8>) -> Dataset {
        assert_eq!(features.len(), labels.len(), "features/labels mismatch");
        assert!(
            labels.iter().all(|&y| y == 1 || y == -1),
            "labels must be +/-1"
        );
        Dataset { features, labels }
    }

    /// Empty dense dataset with a fixed feature dimension.
    pub fn with_dim(dim: usize) -> Dataset {
        assert!(dim > 0, "dim must be positive");
        Dataset { features: Features::dense_with_dim(dim), labels: Vec::new() }
    }

    /// Empty CSR-sparse dataset with a fixed feature dimension.
    pub fn sparse_with_dim(dim: usize) -> Dataset {
        Dataset { features: Features::sparse_with_dim(dim), labels: Vec::new() }
    }

    /// Empty dataset with the same backend and dimension as `self`.
    pub fn empty_like(&self) -> Dataset {
        Dataset { features: self.features.empty_like(), labels: Vec::new() }
    }

    /// Append one dense example (the sparse backend keeps only its
    /// non-zero coordinates — see `data::features` for why that is
    /// bit-exact).
    pub fn push(&mut self, x: &[f32], y: i8) {
        assert!(y == 1 || y == -1);
        self.features.push_dense(x);
        self.labels.push(y);
    }

    /// Append one example from a row view, preserving this dataset's
    /// backend.
    pub fn push_row(&mut self, x: Row<'_>, y: i8) {
        assert!(y == 1 || y == -1);
        self.features.push_row(x);
        self.labels.push(y);
    }

    /// Number of examples ℓ.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Is the dataset empty?
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Feature dimension d.
    pub fn dim(&self) -> usize {
        self.features.dim()
    }

    /// Feature row `i` as a dense slice. Dense storage only — sparse
    /// datasets panic here; backend-agnostic callers use
    /// [`Dataset::row_ref`].
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        match &self.features {
            Features::Dense { dim, rows } => &rows[i * dim..(i + 1) * dim],
            Features::Sparse { .. } => {
                assert!(
                    !self.features.is_sparse(),
                    "row(): dense slice requested from sparse storage; use row_ref()"
                );
                &[]
            }
        }
    }

    /// Zero-copy view of feature row `i`, from either backend.
    #[inline]
    pub fn row_ref(&self, i: usize) -> Row<'_> {
        self.features.row(i)
    }

    /// Label of example `i` (±1).
    #[inline]
    pub fn label(&self, i: usize) -> i8 {
        self.labels[i]
    }

    /// All labels.
    pub fn labels(&self) -> &[i8] {
        &self.labels
    }

    /// Raw row-major feature buffer. Dense storage only — sparse
    /// datasets panic here; backend-agnostic callers go through
    /// [`Dataset::storage`] / [`Dataset::row_ref`].
    pub fn features(&self) -> &[f32] {
        match &self.features {
            Features::Dense { rows, .. } => rows,
            Features::Sparse { .. } => {
                assert!(
                    !self.features.is_sparse(),
                    "features(): row-major buffer requested from sparse storage"
                );
                &[]
            }
        }
    }

    /// The backing feature matrix.
    pub fn storage(&self) -> &Features {
        &self.features
    }

    /// True when features are CSR-sparse.
    pub fn is_sparse(&self) -> bool {
        self.features.is_sparse()
    }

    /// Stored feature entries (dense rows store every coordinate).
    pub fn nnz(&self) -> usize {
        self.features.nnz()
    }

    /// Heap bytes held by features + labels (the bytes-resident column
    /// of the density-sweep benches).
    pub fn resident_bytes(&self) -> usize {
        self.features.resident_bytes() + self.labels.len()
    }

    /// A dense-storage copy of this dataset (identity when already
    /// dense).
    pub fn to_dense(&self) -> Dataset {
        Dataset { features: self.features.to_dense(), labels: self.labels.clone() }
    }

    /// A CSR-sparse copy of this dataset (identity when already sparse).
    pub fn to_sparse(&self) -> Dataset {
        Dataset { features: self.features.to_sparse(), labels: self.labels.clone() }
    }

    /// Counts of (positive, negative) labels.
    pub fn class_counts(&self) -> (usize, usize) {
        let pos = self.labels.iter().filter(|&&y| y == 1).count();
        (pos, self.labels.len() - pos)
    }

    /// New dataset with rows gathered by `idx` (`idx[i]` = source row),
    /// preserving the storage backend. One bulk gather on the feature
    /// matrix — the already-validated source rows need no per-row
    /// shape/label asserts, which matters on the CV-split path where
    /// every fold of every grid point re-materializes its subsets.
    fn gather(&self, idx: &[usize]) -> Dataset {
        let mut labels = Vec::with_capacity(idx.len());
        for &src in idx {
            labels.push(self.labels[src]);
        }
        Dataset { features: self.features.gather(idx), labels }
    }

    /// New dataset with rows reordered by `perm` (perm[i] = source index).
    pub fn permuted(&self, perm: &[usize]) -> Dataset {
        assert_eq!(perm.len(), self.len());
        self.gather(perm)
    }

    /// Subset by index list (used by CV splits).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        self.gather(idx)
    }

    /// Squared Euclidean distance between rows i and j. Differences are
    /// taken in f32 then squared/accumulated in f64 (the historical
    /// dense arithmetic, preserved bit-for-bit; sparse rows skip
    /// both-zero coordinates, which contribute exactly `+0.0`).
    #[inline]
    pub fn sqdist(&self, i: usize, j: usize) -> f64 {
        match &self.features {
            Features::Dense { dim, rows } => {
                let (a, b) = (&rows[i * dim..(i + 1) * dim], &rows[j * dim..(j + 1) * dim]);
                let mut s = 0.0f64;
                for k in 0..*dim {
                    let d = (a[k] - b[k]) as f64;
                    s += d * d;
                }
                s
            }
            Features::Sparse { .. } => sqdist_f32(self.row_ref(i), self.row_ref(j)),
        }
    }
}

/// Union-merge sqdist with f32 differences (the [`Dataset::sqdist`]
/// arithmetic), for sparse rows.
fn sqdist_f32(a: Row<'_>, b: Row<'_>) -> f64 {
    let mut s = 0.0f64;
    match (a, b) {
        (
            Row::Sparse { indices: ia, values: va, .. },
            Row::Sparse { indices: ib, values: vb, .. },
        ) => {
            let (mut p, mut q) = (0usize, 0usize);
            while p < ia.len() || q < ib.len() {
                let d = if q >= ib.len() || (p < ia.len() && ia[p] < ib[q]) {
                    let d = va[p] - 0.0;
                    p += 1;
                    d
                } else if p >= ia.len() || ib[q] < ia[p] {
                    let d = 0.0 - vb[q];
                    q += 1;
                    d
                } else {
                    let d = va[p] - vb[q];
                    p += 1;
                    q += 1;
                    d
                };
                let d = d as f64;
                s += d * d;
            }
        }
        (a, b) => {
            // Mixed backends: walk every coordinate of the dense side.
            let (av, bv) = (a.to_vec(), b.to_vec());
            for k in 0..av.len().min(bv.len()) {
                let d = (av[k] - bv[k]) as f64;
                s += d * d;
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new(2, vec![0.0, 0.0, 1.0, 0.0, 0.0, 2.0], vec![1, -1, 1])
    }

    #[test]
    fn basic_accessors() {
        let d = toy();
        assert_eq!(d.len(), 3);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.row(1), &[1.0, 0.0]);
        assert_eq!(d.label(1), -1);
        assert_eq!(d.class_counts(), (2, 1));
        assert!(!d.is_sparse());
        assert_eq!(d.features().len(), 6);
    }

    #[test]
    fn sqdist_matches_hand_computation() {
        let d = toy();
        assert_eq!(d.sqdist(0, 1), 1.0);
        assert_eq!(d.sqdist(0, 2), 4.0);
        assert_eq!(d.sqdist(1, 2), 5.0);
        assert_eq!(d.sqdist(2, 2), 0.0);
    }

    #[test]
    fn permuted_reorders_rows_and_labels() {
        let d = toy();
        let p = d.permuted(&[2, 0, 1]);
        assert_eq!(p.row(0), d.row(2));
        assert_eq!(p.label(0), d.label(2));
        assert_eq!(p.row(2), d.row(1));
        assert_eq!(p.len(), 3);
    }

    #[test]
    fn subset_picks_rows() {
        let d = toy();
        let s = d.subset(&[0, 2]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.row(1), d.row(2));
    }

    #[test]
    fn subset_of_permuted_equals_composed_indexing() {
        let d = Dataset::new(
            2,
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0],
            vec![1, -1, 1, -1, 1],
        );
        let perm = [4usize, 2, 0, 3, 1];
        let idx = [1usize, 1, 4, 0];
        let two_step = d.permuted(&perm).subset(&idx);
        let composed: Vec<usize> = idx.iter().map(|&i| perm[i]).collect();
        let direct = d.subset(&composed);
        assert_eq!(two_step, direct);
        // repeats are allowed in subsets
        assert_eq!(two_step.row(0), two_step.row(1));
        assert_eq!(two_step.row(0), d.row(2));
    }

    #[test]
    #[should_panic(expected = "labels must be")]
    fn rejects_bad_labels() {
        Dataset::new(1, vec![0.0], vec![0]);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn rejects_shape_mismatch() {
        Dataset::new(2, vec![0.0; 5], vec![1, -1]);
    }

    #[test]
    fn sparse_dataset_mirrors_dense_semantics() {
        let dense = toy();
        let sparse = dense.to_sparse();
        assert!(sparse.is_sparse());
        assert_eq!(sparse.len(), 3);
        assert_eq!(sparse.dim(), 2);
        assert_eq!(sparse.labels(), dense.labels());
        for i in 0..3 {
            assert_eq!(sparse.row_ref(i).to_vec(), dense.row(i));
            for j in 0..3 {
                assert_eq!(
                    sparse.sqdist(i, j).to_bits(),
                    dense.sqdist(i, j).to_bits(),
                    "sqdist {i},{j}"
                );
            }
        }
        // round trip back to dense restores equality
        assert_eq!(sparse.to_dense(), dense);
        // permuted/subset stay sparse and match the dense gather
        let p = sparse.permuted(&[2, 0, 1]);
        assert!(p.is_sparse());
        assert_eq!(p.to_dense(), dense.permuted(&[2, 0, 1]));
        let s = sparse.subset(&[0, 2, 2]);
        assert!(s.is_sparse());
        assert_eq!(s.to_dense(), dense.subset(&[0, 2, 2]));
    }

    #[test]
    #[should_panic(expected = "dense slice requested from sparse storage")]
    fn dense_row_accessor_refuses_sparse_storage() {
        let sparse = toy().to_sparse();
        let _ = sparse.row(0);
    }

    #[test]
    fn push_row_preserves_backend_and_bytes_track_storage() {
        let dense = toy();
        let mut sp = Dataset::sparse_with_dim(2);
        for i in 0..dense.len() {
            sp.push_row(dense.row_ref(i), dense.label(i));
        }
        assert!(sp.is_sparse());
        assert_eq!(sp.to_dense(), dense);
        // toy() rows are mostly zeros: CSR holds 3 of 6 cells
        assert_eq!(sp.nnz(), 3);
        assert!(sp.resident_bytes() > 0);
    }
}
