//! Chaos suite: overload shedding, panic quarantine, artifact write
//! faults, checkpoint kill/resume, and hot-swap under concurrent load.
//!
//! Run with `cargo test --features fault-injection --test chaos` (ci.sh
//! does). The fault-driven tests are compiled out without the feature —
//! [`pasmo::faults::set_plan`] is a no-op there — while the purely
//! behavioral tests (flood shedding, kill/resume, hot-swap) run either
//! way. The fault plan is process-global, so every test in this file
//! serializes on one lock: a plan armed for one server must never fire
//! inside another test's scoring loop.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use pasmo::data::synth::chessboard;
use pasmo::server::{request_once, ServeConfig, Server};
use pasmo::solver::{Checkpoint, StopReason};
use pasmo::svm::schema::AnyModel;
use pasmo::svm::Trainer;
use pasmo::util::json::Json;

/// Serialize every chaos test (see the module docs).
fn chaos_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Per-test scratch directory, removed on drop.
struct TempDir(PathBuf);

impl TempDir {
    fn new(test: &str) -> TempDir {
        let dir = std::env::temp_dir().join(format!(
            "pasmo-chaos-{test}-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }

    fn path(&self, name: &str) -> PathBuf {
        self.0.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.0).ok();
    }
}

/// One persistent client connection speaking newline-delimited JSON.
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn open(addr: SocketAddr) -> Conn {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        stream.set_nodelay(true).unwrap();
        Conn {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
    }

    fn recv(&mut self) -> String {
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "server closed the connection");
        reply.trim_end().to_string()
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.send(line);
        self.recv()
    }
}

fn score_line(x: &[f32], id: usize) -> String {
    let feats: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!("{{\"x\":[{}],\"id\":{id}}}", feats.join(","))
}

/// Train a tiny 2-d classifier for serving tests.
fn tiny_model(seed: u64) -> pasmo::svm::SvmModel {
    let ds = Arc::new(chessboard(120, 4, seed));
    Trainer::rbf(10.0, 0.5).train(&ds).model
}

/// Bind a server, run it on a thread, return the handle + address.
fn spawn_server(
    config: ServeConfig,
    models: Vec<(String, AnyModel)>,
) -> (std::thread::JoinHandle<pasmo::util::error::Result<()>>, SocketAddr) {
    let server = Server::bind(config, models).unwrap();
    let addr = server.local_addr();
    (std::thread::spawn(move || server.run()), addr)
}

fn shutdown(addr: SocketAddr, handle: std::thread::JoinHandle<pasmo::util::error::Result<()>>) {
    let _ = request_once(addr, "{\"cmd\":\"shutdown\"}").unwrap();
    handle.join().unwrap().unwrap();
}

/// Flood a bounded admission queue: the overflow is shed with an
/// explicit reply, and an established connection keeps working across
/// the whole storm — overload never turns into dropped connections.
#[test]
fn flood_sheds_overflow_without_dropping_established_connections() {
    let _g = chaos_lock();
    pasmo::faults::reset();
    let (handle, addr) = spawn_server(
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            // max_batch 2 keeps the 200 ms admission window open (the
            // queue drains only at window close), so a pipelined burst
            // deterministically finds the one-slot queue full
            max_batch: 2,
            max_wait_us: 200_000,
            threads: 1,
            max_queue: 1,
            ..ServeConfig::default()
        },
        vec![("m".to_string(), AnyModel::Svc(tiny_model(3)))],
    );

    let mut established = Conn::open(addr);
    let first = established.roundtrip(&score_line(&[0.25, 0.75], 1));
    assert!(first.contains("\"ok\":true"), "{first}");

    let mut flood = Conn::open(addr);
    let burst = 8;
    for i in 0..burst {
        flood.send(&score_line(&[0.5, 0.5], 100 + i));
    }
    let (mut ok, mut shed) = (0, 0);
    for _ in 0..burst {
        let reply = flood.recv();
        if reply.contains("queue is full") {
            assert!(reply.contains("\"ok\":false"), "{reply}");
            shed += 1;
        } else {
            assert!(reply.contains("\"ok\":true"), "{reply}");
            ok += 1;
        }
    }
    assert_eq!(
        (ok, shed),
        (1, burst - 1),
        "one slot admits one query; the rest shed"
    );

    // the established connection survived the flood untouched
    let again = established.roundtrip(&score_line(&[0.25, 0.75], 2));
    assert!(again.contains("\"ok\":true"), "{again}");

    // the stats counters saw the shed queries
    let stats = Json::parse(&established.roundtrip("{\"cmd\":\"stats\"}")).unwrap();
    assert_eq!(
        stats.get("shed").and_then(|v| v.as_f64()),
        Some((burst - 1) as f64),
        "shed total"
    );
    drop(established);
    drop(flood);
    shutdown(addr, handle);
}

/// An injected panic inside one scoring pass quarantines the model —
/// in-flight queries get error replies, later ones are refused at
/// admission — while the server itself keeps serving, and a hot-reload
/// of the same file restores service on the same connection.
#[cfg(feature = "fault-injection")]
#[test]
fn injected_scoring_panic_quarantines_the_model_not_the_server() {
    let _g = chaos_lock();
    pasmo::faults::reset();
    let dir = TempDir::new("quarantine");
    let model = tiny_model(5);
    let path = dir.path("m.json");
    model.save(&path).unwrap();

    let (handle, addr) = spawn_server(
        ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() },
        vec![("m".to_string(), AnyModel::Svc(model))],
    );
    let mut conn = Conn::open(addr);

    // hit 1 of `server.score_group` is the panic seam of the first
    // scored group (the delay seam of the same group is hit 2)
    pasmo::faults::set_plan("server.score_group@1").unwrap();
    let reply = conn.roundtrip(&score_line(&[0.1, 0.9], 1));
    assert!(reply.contains("quarantined"), "{reply}");
    assert!(reply.contains("\"ok\":false"), "{reply}");
    pasmo::faults::reset();

    // the connection is alive; the model is refused at admission now
    let reply = conn.roundtrip(&score_line(&[0.1, 0.9], 2));
    assert!(reply.contains("quarantined"), "{reply}");

    // stats surface the unhealthy entry
    let stats = conn.roundtrip("{\"cmd\":\"stats\"}");
    assert!(stats.contains("\"healthy\":false"), "{stats}");

    // reloading the same file installs a fresh, healthy generation
    let load = conn.roundtrip(&format!(
        "{{\"cmd\":\"load\",\"name\":\"m\",\"path\":{:?}}}",
        path.to_str().unwrap()
    ));
    assert!(load.contains("\"ok\":true"), "{load}");
    let reply = conn.roundtrip(&score_line(&[0.1, 0.9], 3));
    assert!(reply.contains("\"ok\":true"), "{reply}");
    drop(conn);
    shutdown(addr, handle);
}

/// An injected IO fault mid-save leaves the previous artifact intact,
/// bit for bit, with no temp-file litter — and the very next save
/// succeeds and replaces it atomically.
#[cfg(feature = "fault-injection")]
#[test]
fn injected_write_fault_preserves_the_previous_checkpoint() {
    let _g = chaos_lock();
    pasmo::faults::reset();
    let dir = TempDir::new("write-fault");
    let path = dir.path("ck.json");
    let old = Checkpoint {
        alpha: vec![0.5, 1.0, 0.0],
        iterations: 10,
        objective: 1.5,
        eps: 1e-3,
    };
    old.save(&path).unwrap();

    pasmo::faults::set_plan("artifact.write@1").unwrap();
    let new = Checkpoint {
        alpha: vec![0.25, 0.75, 0.5],
        iterations: 20,
        objective: 2.5,
        eps: 1e-3,
    };
    let err = new.save(&path).unwrap_err().to_string();
    assert!(err.contains("injected IO fault"), "{err}");
    pasmo::faults::reset();

    assert_eq!(Checkpoint::load(&path).unwrap(), old, "old checkpoint must survive");
    let litter: Vec<String> = std::fs::read_dir(&dir.0)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n != "ck.json")
        .collect();
    assert!(litter.is_empty(), "temp files left behind: {litter:?}");

    // a sync-stage fault behaves the same way
    pasmo::faults::set_plan("artifact.sync@1").unwrap();
    assert!(new.save(&path).is_err());
    pasmo::faults::reset();
    assert_eq!(Checkpoint::load(&path).unwrap(), old);

    new.save(&path).unwrap();
    assert_eq!(Checkpoint::load(&path).unwrap(), new);
}

/// Corrupt checkpoints are refused loudly: truncation yields a
/// positioned parse error, a bit-flip a checksum mismatch — neither is
/// ever resumed from.
#[test]
fn corrupt_checkpoints_are_refused_with_positioned_errors() {
    let _g = chaos_lock();
    let dir = TempDir::new("corrupt-ck");
    let path = dir.path("ck.json");
    let ck = Checkpoint {
        alpha: vec![0.125; 40],
        iterations: 777,
        objective: -3.5,
        eps: 1e-3,
    };
    ck.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();

    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    let err = Checkpoint::load(&path).unwrap_err().to_string();
    assert!(err.contains("byte"), "positioned error expected: {err}");

    std::fs::write(&path, text.replace("777", "778")).unwrap();
    let err = Checkpoint::load(&path).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "{err}");

    std::fs::write(&path, &text).unwrap();
    assert_eq!(Checkpoint::load(&path).unwrap(), ck);
}

/// Kill-at-iteration-N: snapshot a solve cut off by its iteration cap
/// (exactly what `pasmo train --checkpoint` persists), resume it in a
/// "fresh process" through the warm-start path, and land on the
/// uninterrupted solve's objective within the stopping accuracy.
#[test]
fn killed_training_resumes_to_the_uninterrupted_objective() {
    let _g = chaos_lock();
    let dir = TempDir::new("kill-resume");
    let ds = Arc::new(chessboard(300, 4, 7));
    let trainer = Trainer::rbf(10.0, 0.5);

    let full = trainer.train(&ds).result;
    assert!(full.converged, "baseline must converge");
    assert!(full.iterations > 80, "need room to interrupt at 60");

    // "crash" at iteration 60: cap the solve and snapshot the iterate
    let mut cfg = trainer.solver_config;
    cfg.max_iter = 60;
    let partial = trainer.clone().solver_config(cfg).train(&ds).result;
    assert_eq!(partial.stop_reason, StopReason::IterLimit);
    assert_eq!(partial.iterations, 60);
    let ck_path = dir.path("ck.json");
    Checkpoint {
        alpha: partial.alpha,
        iterations: partial.iterations,
        objective: partial.objective,
        eps: cfg.eps,
    }
    .save(&ck_path)
    .unwrap();

    // resume from disk only — no state carried over but the file
    let ck = Checkpoint::load(&ck_path).unwrap();
    assert_eq!(ck.iterations, 60);
    let resumed = Trainer::rbf(10.0, 0.5).warm_start(ck.alpha).train(&ds).result;
    assert!(resumed.converged, "resumed solve must converge");
    let scale = full.objective.abs().max(1.0);
    assert!(
        (resumed.objective - full.objective).abs() <= 1e-3 * scale,
        "resumed objective {} vs uninterrupted {} (tolerance {})",
        resumed.objective,
        full.objective,
        1e-3 * scale
    );
    // resuming saved work: the tail is shorter than the whole solve
    assert!(
        resumed.iterations < full.iterations,
        "resumed tail {} !< full solve {}",
        resumed.iterations,
        full.iterations
    );
}

/// Registry hot-swap under concurrent load: clients hammer one model
/// name while the main thread swaps two generations back and forth.
/// Every reply must bit-match one of the two generations — a query
/// scored half-against-one, half-against-the-other is impossible
/// because each query captures its entry Arc at admission.
#[test]
fn hot_swap_under_load_serves_only_whole_generations() {
    let _g = chaos_lock();
    let dir = TempDir::new("hot-swap");
    let ds = Arc::new(chessboard(120, 4, 11));
    let gen_a = Trainer::rbf(100.0, 0.5).train(&ds).model;
    let gen_b = Trainer::rbf(10.0, 1.5).train(&ds).model;
    let path_a = dir.path("a.json");
    let path_b = dir.path("b.json");
    gen_a.save(&path_a).unwrap();
    gen_b.save(&path_b).unwrap();

    let query: Vec<f32> = ds.row(0).to_vec();
    let bits_a = gen_a.decision(&query).to_bits();
    let bits_b = gen_b.decision(&query).to_bits();
    assert_ne!(bits_a, bits_b, "generations must be distinguishable");

    let (handle, addr) = spawn_server(
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_batch: 8,
            max_wait_us: 100,
            threads: 1,
            ..ServeConfig::default()
        },
        vec![("m".to_string(), AnyModel::Svc(gen_a))],
    );

    let clients: Vec<_> = (0..3)
        .map(|c| {
            let query = query.clone();
            std::thread::spawn(move || {
                let mut conn = Conn::open(addr);
                let mut seen = [0u64; 2];
                for i in 0..60 {
                    let reply = conn.roundtrip(&score_line(&query, c * 1000 + i));
                    let v = Json::parse(&reply).unwrap();
                    assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true), "{reply}");
                    let bits =
                        v.get("decision").and_then(|d| d.as_f64()).unwrap().to_bits();
                    if bits == bits_a {
                        seen[0] += 1;
                    } else if bits == bits_b {
                        seen[1] += 1;
                    } else {
                        panic!("reply matches neither generation: {reply}");
                    }
                }
                seen
            })
        })
        .collect();

    // swap generations under the clients' feet
    let mut admin = Conn::open(addr);
    for round in 0..10 {
        let path = if round % 2 == 0 { &path_b } else { &path_a };
        let reply = admin.roundtrip(&format!(
            "{{\"cmd\":\"load\",\"name\":\"m\",\"path\":{:?}}}",
            path.to_str().unwrap()
        ));
        assert!(reply.contains("\"ok\":true"), "{reply}");
        std::thread::sleep(Duration::from_millis(2));
    }

    let mut totals = [0u64; 2];
    for c in clients {
        let seen = c.join().unwrap();
        totals[0] += seen[0];
        totals[1] += seen[1];
    }
    assert_eq!(totals[0] + totals[1], 3 * 60, "every reply matched a generation");
    // the swaps really interleaved with traffic: both generations served
    assert!(
        totals[0] > 0 && totals[1] > 0,
        "expected both generations under load, saw {totals:?}"
    );
    drop(admin);
    shutdown(addr, handle);
}

/// Deadline expiry under injected slowness: a fault-plan delay stretches
/// the first scoring pass past the per-query deadline, and the queries
/// stuck behind it are answered `deadline_exceeded` instead of being
/// scored late.
#[cfg(feature = "fault-injection")]
#[test]
fn injected_slow_pass_expires_queued_queries_at_their_deadline() {
    let _g = chaos_lock();
    pasmo::faults::reset();
    let (handle, addr) = spawn_server(
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            // one query per batch: the injected 25 ms delay on the
            // first scored group holds the second query in the queue
            // well past its 5 ms deadline
            max_batch: 1,
            max_wait_us: 0,
            threads: 1,
            deadline_us: 5_000,
            ..ServeConfig::default()
        },
        vec![("m".to_string(), AnyModel::Svc(tiny_model(13)))],
    );
    // hit 2 of `server.score_group` is the delay seam of the first
    // scored group (hit 1 is its panic seam, which must not fire)
    pasmo::faults::set_plan("server.score_group@2").unwrap();

    let mut conn = Conn::open(addr);
    conn.send(&score_line(&[0.3, 0.7], 1));
    conn.send(&score_line(&[0.6, 0.4], 2));
    let first = conn.recv();
    let second = conn.recv();
    pasmo::faults::reset();

    // query 1 scored (slowly); query 2 sat in the queue past its
    // deadline and was expired without scoring
    assert!(first.contains("\"ok\":true"), "{first}");
    assert!(second.contains("deadline_exceeded"), "{second}");
    let stats = Json::parse(&conn.roundtrip("{\"cmd\":\"stats\"}")).unwrap();
    assert_eq!(stats.get("expired").and_then(|v| v.as_f64()), Some(1.0));
    drop(conn);
    shutdown(addr, handle);
}
