//! End-to-end exercise of the `debug-invariants` checker layer.
//!
//! Every checker in the library panics the moment its invariant is
//! violated, so these tests assert by *finishing*: a full randomized
//! solve — with shrinking forced on a short interval and a cache small
//! enough to churn — that runs to completion under the feature proves
//! the solver never left a state the checkers object to. The targeted
//! corruption tests (each checker fires on a hand-broken structure)
//! live next to the checkers themselves in the library's test modules.

#![cfg(feature = "debug-invariants")]

use std::sync::Arc;

use pasmo::data::dataset::Dataset;
use pasmo::kernel::function::KernelFunction;
use pasmo::kernel::matrix::Gram;
use pasmo::kernel::native::NativeRowComputer;
use pasmo::solver::{Engine, PasmoSolver, QpProblem, SmoSolver, SolverConfig};
use pasmo::util::prng::Pcg;
use pasmo::util::quickcheck::forall;

/// Two noisy Gaussian blobs with alternating labels — separable enough
/// that solves terminate quickly, overlapping enough that some α end up
/// strictly inside the box (free variables exercise the unshrink path).
fn blob_dataset(n: usize, rng: &mut Pcg) -> (Arc<Dataset>, Vec<i8>) {
    let mut ds = Dataset::with_dim(2);
    for k in 0..n {
        let y: i8 = if k % 2 == 0 { 1 } else { -1 };
        let center = y as f64 * 0.75;
        ds.push(
            &[
                (center + 0.9 * rng.normal()) as f32,
                (-center + 0.9 * rng.normal()) as f32,
            ],
            y,
        );
    }
    let labels: Vec<i8> = ds.labels().to_vec();
    (Arc::new(ds), labels)
}

#[test]
fn random_solves_with_shrinking_never_trip_invariants() {
    forall(
        "random_solves_with_shrinking_never_trip_invariants",
        12,
        |rng| {
            let n = 20 + rng.below(40);
            let c = [0.1, 1.0, 10.0][rng.below(3)];
            (n, rng.next_u64(), c)
        },
        |&(n, seed, c)| {
            let mut rng = Pcg::new(seed);
            let (ds, labels) = blob_dataset(n, &mut rng);
            let config = SolverConfig {
                // Shrink often so every solve crosses the shrink and
                // unshrink seams several times, not just at convergence.
                shrink_interval: 5,
                ..SolverConfig::default()
            };
            let problem = QpProblem::classification(&labels, c);
            let engines: [&dyn Engine; 2] =
                [&SmoSolver::new(config), &PasmoSolver::new(config)];
            for engine in engines {
                let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.5 });
                // 64 KiB cache: small enough that rows are evicted
                // mid-solve, so the RowCache validator sees real churn.
                let mut gram = Gram::new(Box::new(nc), 1 << 16);
                let result = engine.solve(&problem, &mut gram);
                if result.alpha.len() != n {
                    return Err(format!("alpha has {} entries, expected {n}", result.alpha.len()));
                }
                if result.alpha.iter().any(|a| !a.is_finite()) {
                    return Err("non-finite alpha in solve result".to_string());
                }
            }
            Ok(())
        },
    );
}
