//! Integration tests across modules: data → kernel → solver → svm →
//! runtime, at realistic (small) scales.

use std::sync::Arc;

use pasmo::data::suite;
use pasmo::data::synth::chessboard;
use pasmo::kernel::matrix::{DenseGram, Gram};
use pasmo::kernel::{KernelFunction, NativeRowComputer};
use pasmo::solver::reference::solve_reference;
use pasmo::solver::smo::{SolverConfig, WssKind};
use pasmo::svm::train::{train, SolverChoice, TrainConfig};

#[cfg(feature = "pjrt")]
fn artifacts_available() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/MANIFEST.json")
        .exists()
}

/// Every dataset family in the suite trains to convergence at small scale
/// with both solvers, and PA-SMO's objective is never (meaningfully) worse.
#[test]
fn suite_smoke_all_families_converge() {
    for name in ["banana", "twonorm", "ringnorm", "waveform", "tic-tac-toe", "chess-board-1000"] {
        let spec = suite::find(name).unwrap();
        let ds = Arc::new(spec.generate(180, 11));
        let base = TrainConfig::new(spec.c, spec.gamma);
        let (_, smo) = train(&ds, &base.with_solver(SolverChoice::Smo));
        let (_, pa) = train(&ds, &base.with_solver(SolverChoice::Pasmo));
        assert!(smo.converged, "{name}: SMO did not converge");
        assert!(pa.converged, "{name}: PA-SMO did not converge");
        assert!(
            pa.objective >= smo.objective - 1e-3 * (1.0 + smo.objective.abs()),
            "{name}: PA objective {} below SMO {}",
            pa.objective,
            smo.objective
        );
    }
}

/// The paper's headline in miniature: on the chess-board problem PA-SMO
/// needs no more iterations than SMO (usually fewer).
#[test]
fn pasmo_reduces_iterations_on_chessboard() {
    let mut wins = 0usize;
    let mut total_smo = 0u64;
    let mut total_pa = 0u64;
    for seed in 0..5u64 {
        let ds = Arc::new(chessboard(400, 4, seed));
        let base = TrainConfig::new(1e6, 0.5);
        let (_, smo) = train(&ds, &base.with_solver(SolverChoice::Smo));
        let (_, pa) = train(&ds, &base.with_solver(SolverChoice::Pasmo));
        assert!(smo.converged && pa.converged, "seed {seed}");
        total_smo += smo.iterations;
        total_pa += pa.iterations;
        if pa.iterations <= smo.iterations {
            wins += 1;
        }
    }
    assert!(
        total_pa < total_smo,
        "PA-SMO total iterations {total_pa} not below SMO {total_smo}"
    );
    assert!(wins >= 3, "PA-SMO won only {wins}/5 runs");
}

/// Cross-check all four solver configurations against the independent
/// dense projected-gradient oracle on one problem.
#[test]
fn all_solver_variants_agree_with_oracle() {
    let ds = Arc::new(chessboard(80, 4, 3));
    let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.5 });
    let dense = DenseGram::materialize(&nc);
    let oracle = solve_reference(&dense, ds.labels(), 10.0, 300_000, 1e-14);
    let tol = 1e-3 * (1.0 + oracle.objective.abs());

    for (label, choice) in [
        ("smo", SolverChoice::Smo),
        ("pasmo", SolverChoice::Pasmo),
        ("multi3", SolverChoice::PasmoMulti(3)),
    ] {
        let cfg = TrainConfig::new(10.0, 0.5).with_solver(choice);
        let (_, res) = train(&ds, &cfg);
        assert!(
            (res.objective - oracle.objective).abs() < tol,
            "{label}: {} vs oracle {}",
            res.objective,
            oracle.objective
        );
    }
    // first-order WSS too
    let mut cfg = TrainConfig::new(10.0, 0.5).with_solver(SolverChoice::Smo);
    cfg.solver_config = SolverConfig { wss: WssKind::MaxViolating, ..Default::default() };
    let (_, res) = train(&ds, &cfg);
    assert!((res.objective - oracle.objective).abs() < tol, "mvp wss");
}

/// PJRT-backed training produces the same model quality as native.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_and_native_training_agree() {
    use pasmo::runtime::engine::PjrtEngine;
    use pasmo::runtime::gram::PjrtRowComputer;
    use pasmo::svm::predict::accuracy;
    use pasmo::svm::train::train_with_computer;
    use std::rc::Rc;

    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let ds = Arc::new(chessboard(300, 4, 7));
    let cfg = TrainConfig::new(1e4, 0.5);
    let (m_native, r_native) = train(&ds, &cfg);
    let engine = Rc::new(PjrtEngine::open_default().unwrap());
    let computer = PjrtRowComputer::new(engine, ds.clone(), 0.5).unwrap();
    let (m_pjrt, r_pjrt) = train_with_computer(&ds, &cfg, Box::new(computer));
    assert!(r_native.converged && r_pjrt.converged);
    let rel =
        (r_native.objective - r_pjrt.objective).abs() / (1.0 + r_native.objective.abs());
    assert!(rel < 5e-3, "objectives differ: {} vs {}", r_native.objective, r_pjrt.objective);
    let test = chessboard(500, 4, 8);
    let (a1, a2) = (accuracy(&m_native, &test), accuracy(&m_pjrt, &test));
    assert!((a1 - a2).abs() < 0.05, "accuracies differ: {a1} vs {a2}");
}

/// Smoke test for the `pjrt` feature: the runtime layer must compile and
/// fail *cleanly* (a chained error, not a panic) when no artifacts /
/// PJRT plugin are available — which is always the case with the offline
/// `vendor/xla` stub. Guards against the offline build silently regrowing
/// a hard `xla` dependency with undefined failure modes.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_engine_reports_clean_error_without_artifacts() {
    use pasmo::runtime::engine::PjrtEngine;

    if artifacts_available() {
        eprintln!("skipping: artifacts present (covered by pjrt_and_native_training_agree)");
        return;
    }
    let dir = std::env::temp_dir().join("pasmo-pjrt-smoke-no-artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::remove_file(dir.join("MANIFEST.json")).ok();
    let err = match PjrtEngine::open(&dir) {
        Ok(_) => panic!("engine must not open without MANIFEST.json"),
        Err(e) => e,
    };
    let msg = err.to_string();
    assert!(msg.contains("MANIFEST.json"), "unhelpful error: {msg}");
}

/// Solving the same permuted problem twice is bit-identical (determinism
/// underpins the paired experiment design).
#[test]
fn solves_are_deterministic() {
    let ds = Arc::new(chessboard(200, 4, 9));
    let cfg = TrainConfig::new(100.0, 0.5);
    let (_, r1) = train(&ds, &cfg);
    let (_, r2) = train(&ds, &cfg);
    assert_eq!(r1.iterations, r2.iterations);
    assert_eq!(r1.objective, r2.objective);
    assert_eq!(r1.sv, r2.sv);
}

/// Tiny C forces all support vectors to the box bound; huge C leaves them
/// free — the SV/BSV accounting matches the regime.
#[test]
fn c_regime_controls_bounded_svs() {
    let ds = Arc::new(chessboard(200, 4, 10));
    let (_, small_c) = train(&ds, &TrainConfig::new(1e-3, 0.5));
    let (_, large_c) = train(&ds, &TrainConfig::new(1e6, 0.5));
    assert!(small_c.bsv * 10 >= small_c.sv * 9, "tiny C: nearly all bounded");
    assert!(large_c.bsv * 10 <= large_c.sv * 5, "huge C: mostly free SVs");
}

/// Gram facade consistency on a real training run: cache statistics add
/// up and the solver touched the cache.
#[test]
fn cache_statistics_are_consistent() {
    let ds = Arc::new(chessboard(300, 4, 12));
    let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.5 });
    let mut gram = Gram::new(Box::new(nc), 4 << 20);
    let res = pasmo::solver::pasmo::PasmoSolver::new(SolverConfig::default())
        .solve(ds.labels(), 1e6, &mut gram);
    assert!(res.converged);
    let s = res.cache_stats;
    assert!(s.hits > 0, "no cache hits in a full solve?");
    assert!(s.misses > 0);
    assert!(s.hits + s.misses >= 2 * res.iterations, "each iteration touches 2 rows");
}
