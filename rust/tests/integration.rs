//! Integration tests across modules: data → kernel → solver → svm →
//! runtime, at realistic (small) scales.

use std::sync::Arc;

use pasmo::data::suite;
use pasmo::data::synth::chessboard;
use pasmo::kernel::matrix::{DenseGram, Gram, RowComputer};
use pasmo::kernel::{KernelFunction, NativeRowComputer};
use pasmo::solver::reference::solve_reference;
use pasmo::solver::smo::{SolverConfig, WssKind};
use pasmo::solver::{Engine, PasmoSolver, QpProblem, SolverState};
use pasmo::svm::{SolverChoice, Trainer};

#[cfg(feature = "pjrt")]
fn artifacts_available() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/MANIFEST.json")
        .exists()
}

/// Every dataset family in the suite trains to convergence at small scale
/// with both solvers, and PA-SMO's objective is never (meaningfully) worse.
#[test]
fn suite_smoke_all_families_converge() {
    for name in ["banana", "twonorm", "ringnorm", "waveform", "tic-tac-toe", "chess-board-1000"] {
        let spec = suite::find(name).unwrap();
        let ds = Arc::new(spec.generate(180, 11));
        let base = Trainer::rbf(spec.c, spec.gamma);
        let smo = base.clone().solver(SolverChoice::Smo).train(&ds).result;
        let pa = base.solver(SolverChoice::Pasmo).train(&ds).result;
        assert!(smo.converged, "{name}: SMO did not converge");
        assert!(pa.converged, "{name}: PA-SMO did not converge");
        assert!(
            pa.objective >= smo.objective - 1e-3 * (1.0 + smo.objective.abs()),
            "{name}: PA objective {} below SMO {}",
            pa.objective,
            smo.objective
        );
    }
}

/// The paper's headline in miniature: on the chess-board problem PA-SMO
/// needs no more iterations than SMO (usually fewer).
#[test]
fn pasmo_reduces_iterations_on_chessboard() {
    let mut wins = 0usize;
    let mut total_smo = 0u64;
    let mut total_pa = 0u64;
    for seed in 0..5u64 {
        let ds = Arc::new(chessboard(400, 4, seed));
        let base = Trainer::rbf(1e6, 0.5);
        let smo = base.clone().solver(SolverChoice::Smo).train(&ds).result;
        let pa = base.solver(SolverChoice::Pasmo).train(&ds).result;
        assert!(smo.converged && pa.converged, "seed {seed}");
        total_smo += smo.iterations;
        total_pa += pa.iterations;
        if pa.iterations <= smo.iterations {
            wins += 1;
        }
    }
    assert!(
        total_pa < total_smo,
        "PA-SMO total iterations {total_pa} not below SMO {total_smo}"
    );
    assert!(wins >= 3, "PA-SMO won only {wins}/5 runs");
}

/// Cross-check all four solver configurations against the independent
/// dense projected-gradient oracle on one problem.
#[test]
fn all_solver_variants_agree_with_oracle() {
    let ds = Arc::new(chessboard(80, 4, 3));
    let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.5 });
    let dense = DenseGram::materialize(&nc);
    let oracle = solve_reference(&dense, ds.labels(), 10.0, 300_000, 1e-14);
    let tol = 1e-3 * (1.0 + oracle.objective.abs());

    for (label, choice) in [
        ("smo", SolverChoice::Smo),
        ("pasmo", SolverChoice::Pasmo),
        ("multi3", SolverChoice::PasmoMulti(3)),
        ("conjugate", SolverChoice::ConjugateSmo),
    ] {
        let res = Trainer::rbf(10.0, 0.5).solver(choice).train(&ds).result;
        assert!(
            (res.objective - oracle.objective).abs() < tol,
            "{label}: {} vs oracle {}",
            res.objective,
            oracle.objective
        );
    }
    // first-order WSS too
    let trainer = Trainer::rbf(10.0, 0.5)
        .solver(SolverChoice::Smo)
        .solver_config(SolverConfig { wss: WssKind::MaxViolating, ..Default::default() });
    let res = trainer.train(&ds).result;
    assert!((res.objective - oracle.objective).abs() < tol, "mvp wss");
}

/// The PR-4 acceptance property: all three first-class engines — SMO,
/// PA-SMO and Conjugate SMO — reach the reference-oracle objective
/// within tolerance on the quickcheck problem family, in a plain run,
/// an aggressively *shrink-enabled* run, and a run *warm-started* from
/// the shrunk solution (which must converge almost immediately and stay
/// at the optimum).
#[test]
fn three_way_engine_parity_on_quickcheck_family() {
    use pasmo::util::quickcheck::forall;
    forall(
        "three-way-engine-parity",
        5,
        |g| (30 + g.below(40), g.next_u64(), 10f64.powf(g.range(-0.5, 2.0))),
        |&(n, seed, c)| {
            let ds = Arc::new(chessboard(n, 4, seed));
            let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.5 });
            let dense = DenseGram::materialize(&nc);
            let oracle = solve_reference(&dense, ds.labels(), c, 300_000, 1e-14);
            let tol = 1e-3 * (1.0 + oracle.objective.abs());
            for choice in
                [SolverChoice::Smo, SolverChoice::Pasmo, SolverChoice::ConjugateSmo]
            {
                // Shrink-enabled run with an aggressive period, so the
                // active prefix really contracts at these tiny sizes.
                let shrunk = Trainer::rbf(c, 0.5)
                    .solver(choice)
                    .solver_config(SolverConfig {
                        shrinking: true,
                        shrink_interval: 5,
                        ..Default::default()
                    })
                    .train(&ds)
                    .result;
                if !shrunk.converged {
                    return Err(format!("{choice:?}: shrink-enabled run did not converge"));
                }
                if (shrunk.objective - oracle.objective).abs() > tol {
                    return Err(format!(
                        "{choice:?}: shrunk objective {} vs oracle {}",
                        shrunk.objective, oracle.objective
                    ));
                }
                // Warm-started from that solution: still at the optimum,
                // in (almost) no iterations.
                let warm = Trainer::rbf(c, 0.5)
                    .solver(choice)
                    .warm_start(shrunk.alpha.clone())
                    .train(&ds)
                    .result;
                if !warm.converged {
                    return Err(format!("{choice:?}: warm-started run did not converge"));
                }
                if (warm.objective - oracle.objective).abs() > tol {
                    return Err(format!(
                        "{choice:?}: warm objective {} vs oracle {}",
                        warm.objective, oracle.objective
                    ));
                }
                if warm.iterations > shrunk.iterations / 2 + 10 {
                    return Err(format!(
                        "{choice:?}: warm start did not help ({} vs cold {})",
                        warm.iterations, shrunk.iterations
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The conjugate engine drives the *general* QP shapes through the same
/// passthrough as the other engines: ε-SVR (doubled variables) and
/// one-class (Σα = 1, non-trivial warm start) train to the same
/// objective as PA-SMO on the identical problem.
#[test]
fn conjugate_engine_handles_svr_and_one_class() {
    use pasmo::data::regression::sinc;
    use pasmo::svm::oneclass::{train_one_class, OneClassConfig};
    use pasmo::svm::svr::{train_svr_native, SvrConfig};
    use pasmo::util::prng::Pcg;

    // ε-SVR on the doubled dual.
    let data = sinc(120, 0.05, 3);
    let mut cfg = SvrConfig::new(5.0, 0.1, 0.5);
    cfg.solver = SolverChoice::ConjugateSmo;
    let (_, cj) = train_svr_native(&data, &cfg);
    assert!(cj.converged, "conjugate ε-SVR did not converge");
    let mut pa_cfg = SvrConfig::new(5.0, 0.1, 0.5);
    pa_cfg.solver = SolverChoice::Pasmo;
    let (_, pa) = train_svr_native(&data, &pa_cfg);
    let rel = (cj.objective - pa.objective).abs() / (1.0 + pa.objective.abs());
    assert!(rel < 2e-3, "SVR objectives diverge: {} vs {}", cj.objective, pa.objective);

    // One-class with its feasible LIBSVM-style fill as warm start.
    let mut rng = Pcg::new(77);
    let mut blob = pasmo::data::Dataset::with_dim(2);
    for _ in 0..150 {
        blob.push(&[rng.normal() as f32, rng.normal() as f32], 1);
    }
    let blob = Arc::new(blob);
    let mut oc = OneClassConfig::new(0.2, 0.5);
    oc.solver = SolverChoice::ConjugateSmo;
    let (model, cj) = train_one_class(&blob, &oc);
    assert!(cj.converged, "conjugate one-class did not converge");
    let mut oc_pa = OneClassConfig::new(0.2, 0.5);
    oc_pa.solver = SolverChoice::Pasmo;
    let (_, pa) = train_one_class(&blob, &oc_pa);
    let rel = (cj.objective - pa.objective).abs() / (1.0 + pa.objective.abs());
    assert!(rel < 2e-3, "one-class objectives diverge: {} vs {}", cj.objective, pa.objective);
    // ν bounds the outlier fraction: most of the blob is inside.
    let inliers = (0..blob.len()).filter(|&i| model.is_inlier(blob.row(i))).count();
    assert!(inliers as f64 / blob.len() as f64 > 0.6, "{inliers} inliers");
}

/// API-parity: the `Trainer`/`QpProblem` path reproduces the seed
/// `train` path — an explicit `SolverState::new` handed straight to
/// PA-SMO — bit for bit (objective, iterations, SV counts) across the
/// synthetic suite.
#[test]
fn trainer_path_reproduces_direct_state_path() {
    for name in ["banana", "twonorm", "chess-board-1000"] {
        let spec = suite::find(name).unwrap();
        let ds = Arc::new(spec.generate(160, 5));
        let new_path = Trainer::rbf(spec.c, spec.gamma).train(&ds).result;

        let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: spec.gamma });
        let cfg = SolverConfig::default();
        let mut gram = Gram::new(Box::new(nc), cfg.cache_bytes);
        let old_path = PasmoSolver::new(cfg)
            .solve_state(SolverState::new(ds.labels(), spec.c), &mut gram);

        assert_eq!(new_path.iterations, old_path.iterations, "{name}");
        assert_eq!(new_path.objective, old_path.objective, "{name}");
        assert_eq!((new_path.sv, new_path.bsv), (old_path.sv, old_path.bsv), "{name}");
        assert_eq!(new_path.alpha, old_path.alpha, "{name}");
    }
}

/// API-parity for ε-SVR: `train_svr` (QpProblem::svr lowering)
/// reproduces the seed's hand-built doubled `SolverState` exactly.
#[test]
fn svr_path_reproduces_direct_state_path() {
    use pasmo::data::regression::sinc;
    use pasmo::svm::svr::{train_svr_native, SvrConfig};

    let data = sinc(120, 0.05, 3);
    let cfg = SvrConfig::new(5.0, 0.1, 0.5);
    let (_, new_path) = train_svr_native(&data, &cfg);

    // The seed lowering, spelled out by hand over the doubled kernel.
    let l = data.len();
    let mut ds = pasmo::data::Dataset::with_dim(data.dim());
    for i in 0..l {
        ds.push(data.row(i), 1);
    }
    let ds = Arc::new(ds);
    struct Doubled(NativeRowComputer, usize);
    impl RowComputer for Doubled {
        fn len(&self) -> usize {
            2 * self.1
        }
        fn compute_row(&self, a: usize, out: &mut [f32]) {
            let (lo, hi) = out.split_at_mut(self.1);
            self.0.compute_row(a % self.1, lo);
            hi.copy_from_slice(lo);
        }
        fn diag(&self, a: usize) -> f64 {
            self.0.diag(a % self.1)
        }
        fn entry(&self, a: usize, b: usize) -> f64 {
            self.0.entry(a % self.1, b % self.1)
        }
    }
    let inner = NativeRowComputer::new(ds, KernelFunction::Rbf { gamma: 0.5 });
    let mut gram = Gram::new(Box::new(Doubled(inner, l)), cfg.solver_config.cache_bytes);
    let mut p = Vec::new();
    let mut lower = Vec::new();
    let mut upper = Vec::new();
    for i in 0..l {
        p.push(data.target(i) - cfg.epsilon);
        lower.push(0.0);
        upper.push(cfg.c);
    }
    for i in 0..l {
        p.push(data.target(i) + cfg.epsilon);
        lower.push(-cfg.c);
        upper.push(0.0);
    }
    let state = SolverState::from_problem(p.clone(), lower, upper, vec![0.0; 2 * l], p);
    let old_path = PasmoSolver::new(cfg.solver_config).solve_state(state, &mut gram);

    assert_eq!(new_path.iterations, old_path.iterations);
    assert_eq!(new_path.objective, old_path.objective);
    assert_eq!((new_path.sv, new_path.bsv), (old_path.sv, old_path.bsv));
}

/// API-parity for one-class: `train_one_class` (QpProblem::one_class
/// lowering) reproduces the seed's LIBSVM-style fill + hand-built
/// gradient exactly.
#[test]
fn one_class_path_reproduces_direct_state_path() {
    use pasmo::svm::oneclass::{train_one_class, OneClassConfig};
    use pasmo::util::prng::Pcg;

    let mut rng = Pcg::new(21);
    let mut blob = pasmo::data::Dataset::with_dim(2);
    for _ in 0..150 {
        blob.push(&[rng.normal() as f32, rng.normal() as f32], 1);
    }
    let blob = Arc::new(blob);
    let cfg = OneClassConfig::new(0.2, 0.5);
    let (_, new_path) = train_one_class(&blob, &cfg);

    let l = blob.len();
    let ub = 1.0 / (cfg.nu * l as f64);
    let mut alpha0 = vec![0.0f64; l];
    let mut remaining = 1.0f64;
    for a in alpha0.iter_mut() {
        let v = remaining.min(ub);
        *a = v;
        remaining -= v;
        if remaining <= 0.0 {
            break;
        }
    }
    let nc = NativeRowComputer::new(blob.clone(), cfg.kernel);
    let mut gram = Gram::new(Box::new(nc), cfg.solver_config.cache_bytes);
    let mut grad0 = vec![0.0f64; l];
    for (j, &aj) in alpha0.iter().enumerate() {
        if aj == 0.0 {
            continue;
        }
        let row = gram.row(j);
        for (n, g) in grad0.iter_mut().enumerate() {
            *g -= aj * row[n] as f64;
        }
    }
    let state =
        SolverState::from_problem(vec![0.0; l], vec![0.0; l], vec![ub; l], alpha0, grad0);
    let old_path = PasmoSolver::new(cfg.solver_config).solve_state(state, &mut gram);

    assert_eq!(new_path.iterations, old_path.iterations);
    assert_eq!(new_path.objective, old_path.objective);
    assert_eq!((new_path.sv, new_path.bsv), (old_path.sv, old_path.bsv));
}

/// PJRT-backed training produces the same model quality as native.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_and_native_training_agree() {
    use pasmo::runtime::engine::PjrtEngine;
    use pasmo::runtime::gram::PjrtRowComputer;
    use pasmo::svm::predict::accuracy;
    use std::rc::Rc;

    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let ds = Arc::new(chessboard(300, 4, 7));
    let trainer = Trainer::rbf(1e4, 0.5);
    let native = trainer.train(&ds);
    let engine = Rc::new(PjrtEngine::open_default().unwrap());
    let computer = PjrtRowComputer::new(engine, ds.clone(), 0.5).unwrap();
    let pjrt = trainer.train_with_computer(&ds, Box::new(computer));
    assert!(native.result.converged && pjrt.result.converged);
    let rel = (native.result.objective - pjrt.result.objective).abs()
        / (1.0 + native.result.objective.abs());
    assert!(
        rel < 5e-3,
        "objectives differ: {} vs {}",
        native.result.objective,
        pjrt.result.objective
    );
    let test = chessboard(500, 4, 8);
    let (a1, a2) = (accuracy(&native.model, &test), accuracy(&pjrt.model, &test));
    assert!((a1 - a2).abs() < 0.05, "accuracies differ: {a1} vs {a2}");
}

/// Smoke test for the `pjrt` feature: the runtime layer must compile and
/// fail *cleanly* (a chained error, not a panic) when no artifacts /
/// PJRT plugin are available — which is always the case with the offline
/// `vendor/xla` stub. Guards against the offline build silently regrowing
/// a hard `xla` dependency with undefined failure modes.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_engine_reports_clean_error_without_artifacts() {
    use pasmo::runtime::engine::PjrtEngine;

    if artifacts_available() {
        eprintln!("skipping: artifacts present (covered by pjrt_and_native_training_agree)");
        return;
    }
    let dir = std::env::temp_dir().join("pasmo-pjrt-smoke-no-artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::remove_file(dir.join("MANIFEST.json")).ok();
    let err = match PjrtEngine::open(&dir) {
        Ok(_) => panic!("engine must not open without MANIFEST.json"),
        Err(e) => e,
    };
    let msg = err.to_string();
    assert!(msg.contains("MANIFEST.json"), "unhelpful error: {msg}");
}

/// Property (quickcheck substrate): shrinking with prefix compaction —
/// swapped state vectors, permuted Gram view, shortened kernel rows —
/// returns the same alphas/bias/objective as a `shrinking: false` solve
/// of the identical problem, within the reference-solver tolerance, in
/// *original* coordinates.
#[test]
fn shrinking_with_prefix_compaction_matches_unshrunk_solutions() {
    use pasmo::util::quickcheck::forall;
    forall(
        "shrink-prefix-equivalence",
        6,
        |g| (60 + g.below(60), g.next_u64(), 10f64.powf(g.range(-0.5, 2.0))),
        |&(n, seed, c)| {
            let ds = Arc::new(chessboard(n, 4, seed));
            let solve = |shrinking: bool| {
                Trainer::rbf(c, 0.5)
                    .solver_config(SolverConfig {
                        shrinking,
                        shrink_interval: 5, // shrink aggressively
                        eps: 1e-5,
                        ..Default::default()
                    })
                    .train(&ds)
                    .result
            };
            let on = solve(true);
            let off = solve(false);
            if !on.converged || !off.converged {
                return Err("did not converge".into());
            }
            let obj_tol = 1e-3 * (1.0 + off.objective.abs());
            if (on.objective - off.objective).abs() > obj_tol {
                return Err(format!("objective {} vs {}", on.objective, off.objective));
            }
            let tol = 5e-2 * (1.0 + c);
            if (on.bias - off.bias).abs() > tol {
                return Err(format!("bias {} vs {}", on.bias, off.bias));
            }
            for i in 0..ds.len() {
                if (on.alpha[i] - off.alpha[i]).abs() > tol {
                    return Err(format!(
                        "alpha[{i}] {} vs {}",
                        on.alpha[i], off.alpha[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Warm-started CvSession runs behave identically across shrink modes:
/// the α each fold stores is in original coordinates (de-permuted), so a
/// second pass over the same split re-converges almost for free whether
/// or not the first pass shrank.
#[test]
fn warm_started_cv_sessions_agree_across_shrink_modes() {
    use pasmo::svm::crossval::{cross_validate_session, CvSession};
    let ds = chessboard(180, 4, 31);
    let mut accuracies = Vec::new();
    for shrinking in [true, false] {
        let trainer = Trainer::rbf(50.0, 0.5).solver_config(SolverConfig {
            shrinking,
            shrink_interval: 9,
            ..Default::default()
        });
        let mut session = CvSession::new();
        let first = cross_validate_session(&ds, &trainer, 4, 3, &mut session);
        let second = cross_validate_session(&ds, &trainer, 4, 3, &mut session);
        assert!(
            second.total_iterations < first.total_iterations / 4,
            "shrinking={shrinking}: warm pass {} !< cold pass {} / 4 — \
             fold alphas are not valid original-coordinate seeds",
            second.total_iterations,
            first.total_iterations
        );
        accuracies.push((first.mean_accuracy, second.mean_accuracy));
    }
    let (on, off) = (accuracies[0], accuracies[1]);
    assert!((on.0 - off.0).abs() < 0.05, "first-pass accuracy: {on:?} vs {off:?}");
    assert!((on.1 - off.1).abs() < 0.05, "second-pass accuracy: {on:?} vs {off:?}");
}

/// `--threads N` changes only who computes the kernel rows, never their
/// bits: the whole solve trajectory and result are identical. The rows
/// are wide (ℓ·d above the work threshold), so the threaded path really
/// runs.
#[test]
fn threaded_kernel_rows_leave_the_solution_bit_identical() {
    use pasmo::util::prng::Pcg;
    let mut rng = Pcg::new(23);
    let mut ds = pasmo::data::Dataset::with_dim(96);
    let mut row = vec![0f32; 96];
    for k in 0..700 {
        let y: i8 = if k % 2 == 0 { 1 } else { -1 };
        let shift = if y == 1 { 0.4 } else { -0.4 };
        row.iter_mut()
            .for_each(|v| *v = (shift + rng.normal() * 0.8) as f32);
        ds.push(&row, y);
    }
    let ds = Arc::new(ds);
    let single = Trainer::rbf(10.0, 0.02).train(&ds).result;
    let multi = Trainer::rbf(10.0, 0.02).threads(4).train(&ds).result;
    assert_eq!(single.iterations, multi.iterations);
    assert_eq!(single.objective, multi.objective);
    assert_eq!(single.bias, multi.bias);
    assert_eq!(single.alpha, multi.alpha);
}

/// The point of shrink-aware rows: under cache pressure, a shrinking
/// solve computes strictly fewer kernel entries than the same solve with
/// shrinking disabled — rows get shorter as the active prefix contracts.
#[test]
fn shrinking_strictly_reduces_kernel_entries_under_cache_pressure() {
    let ds = Arc::new(chessboard(500, 4, 13));
    let cache = 32 * 500 * 4; // 32 full rows: eviction traffic is real
    let run = |shrinking: bool| {
        Trainer::rbf(1e6, 0.5)
            .solver_config(SolverConfig {
                shrinking,
                shrink_interval: 100,
                cache_bytes: cache,
                ..Default::default()
            })
            .train(&ds)
            .result
    };
    let on = run(true);
    let off = run(false);
    assert!(on.converged && off.converged);
    assert!(
        on.kernel_entries < off.kernel_entries,
        "shrink-on computed {} kernel entries, shrink-off {}",
        on.kernel_entries,
        off.kernel_entries
    );
}

/// Solving the same permuted problem twice is bit-identical (determinism
/// underpins the paired experiment design).
#[test]
fn solves_are_deterministic() {
    let ds = Arc::new(chessboard(200, 4, 9));
    let trainer = Trainer::rbf(100.0, 0.5);
    let r1 = trainer.train(&ds).result;
    let r2 = trainer.train(&ds).result;
    assert_eq!(r1.iterations, r2.iterations);
    assert_eq!(r1.objective, r2.objective);
    assert_eq!(r1.sv, r2.sv);
}

/// Tiny C forces all support vectors to the box bound; huge C leaves them
/// free — the SV/BSV accounting matches the regime.
#[test]
fn c_regime_controls_bounded_svs() {
    let ds = Arc::new(chessboard(200, 4, 10));
    let small_c = Trainer::rbf(1e-3, 0.5).train(&ds).result;
    let large_c = Trainer::rbf(1e6, 0.5).train(&ds).result;
    assert!(small_c.bsv * 10 >= small_c.sv * 9, "tiny C: nearly all bounded");
    assert!(large_c.bsv * 10 <= large_c.sv * 5, "huge C: mostly free SVs");
}

/// Gram facade consistency on a real training run: cache statistics add
/// up and the solver touched the cache.
#[test]
fn cache_statistics_are_consistent() {
    let ds = Arc::new(chessboard(300, 4, 12));
    let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.5 });
    let mut gram = Gram::new(Box::new(nc), 4 << 20);
    let res = PasmoSolver::new(SolverConfig::default())
        .solve(&QpProblem::classification(ds.labels(), 1e6), &mut gram);
    assert!(res.converged);
    let s = res.cache_stats;
    assert!(s.hits > 0, "no cache hits in a full solve?");
    assert!(s.misses > 0);
    assert!(s.hits + s.misses >= 2 * res.iterations, "each iteration touches 2 rows");
}
