//! Inference parity suite: the batch `Scorer` vs the per-example scalar
//! decision loop across all four kernels and every model kind,
//! threaded-vs-single-thread bit-determinism, and save/load round trips
//! for the kind-tagged v2 schemas.

use std::path::PathBuf;
use std::sync::Arc;

use pasmo::data::dataset::Dataset;
use pasmo::data::multiclass::blobs;
use pasmo::data::regression::sinc;
use pasmo::kernel::KernelFunction;
use pasmo::svm::multiclass::{train_ovo, OvoModel};
use pasmo::svm::oneclass::{train_one_class, OneClassConfig, OneClassModel};
use pasmo::svm::predict;
use pasmo::svm::scorer::{ScoreScratch, Scorer, SupportInvariants};
use pasmo::svm::svr::{train_svr_native, SvrConfig, SvrModel};
use pasmo::svm::{SvmModel, Trainer};
use pasmo::util::prng::Pcg;
use pasmo::util::quickcheck::forall;

/// The ≤1e-12 agreement bound, conditioned on the expansion's
/// magnitude: per-term rounding differences (RBF decomposition vs
/// direct ‖a−b‖², collapsed vs expanded linear reduction) accumulate
/// with the ℓ1 coefficient mass, so that mass is the natural scale.
fn tol(coef: &[f64], want: f64) -> f64 {
    1e-12 * (1.0 + want.abs() + coef.iter().map(|c| c.abs()).sum::<f64>())
}

/// The legacy per-example loop every model kind used before the scorer.
fn legacy_decision(
    kernel: KernelFunction,
    sv: &Dataset,
    coef: &[f64],
    offset: f64,
    x: &[f32],
) -> f64 {
    let mut f = offset;
    for s in 0..sv.len() {
        f += coef[s] * kernel.eval(sv.row(s), x);
    }
    f
}

fn random_ds(n: usize, d: usize, rng: &mut Pcg) -> Dataset {
    let mut ds = Dataset::with_dim(d);
    let mut row = vec![0f32; d];
    for _ in 0..n {
        row.iter_mut().for_each(|v| *v = rng.normal() as f32);
        ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
    }
    ds
}

fn temp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pasmo-parity-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Random expansions across all four kernels: the batch scorer agrees
/// with the legacy scalar loop to ≤1e-12 relative everywhere, and the
/// dot-product kernels (whose scalar path shares the tiled dot's exact
/// arithmetic) are bit-identical.
#[test]
fn quickcheck_scorer_matches_scalar_decision_across_kernels() {
    forall(
        "scorer-vs-scalar",
        24,
        |g| {
            let d = 1 + g.below(8);
            let n_sv = 1 + g.below(60);
            let n_q = 1 + g.below(40);
            let sv = random_ds(n_sv, d, g);
            let coef: Vec<f64> = (0..n_sv).map(|_| g.normal() * 3.0).collect();
            let offset = g.normal();
            let queries = random_ds(n_q, d, g);
            let kernel = match g.below(4) {
                0 => KernelFunction::Rbf { gamma: g.range(0.05, 2.0) },
                1 => KernelFunction::Linear,
                2 => KernelFunction::Poly {
                    gamma: g.range(0.1, 1.0),
                    coef0: 1.0,
                    degree: 2 + g.below(3) as u32,
                },
                _ => KernelFunction::Sigmoid { gamma: g.range(0.05, 0.5), coef0: 0.1 },
            };
            (kernel, sv, coef, offset, queries)
        },
        |(kernel, sv, coef, offset, queries)| {
            let scorer = Scorer::new(*kernel, sv, coef, *offset);
            let batch = scorer.decision_values(queries);
            let bitwise = !matches!(*kernel, KernelFunction::Rbf { .. })
                && !scorer.is_collapsed();
            for q in 0..queries.len() {
                let want = legacy_decision(*kernel, sv, coef, *offset, queries.row(q));
                let got = batch[q];
                if bitwise && got.to_bits() != want.to_bits() {
                    return Err(format!("q={q}: {got} != {want} (bitwise)"));
                }
                if (got - want).abs() > tol(coef, want) {
                    return Err(format!("q={q}: {got} vs {want}"));
                }
                // single-query call is bit-identical to the batch entry
                let one = scorer.decision(queries.row(q));
                if one.to_bits() != got.to_bits() {
                    return Err(format!("q={q}: single {one} != batch {got}"));
                }
            }
            // threaded pass is bit-identical to the single-threaded one
            let threaded = Scorer::new(*kernel, sv, coef, *offset)
                .with_threads(4)
                .decision_values(queries);
            for q in 0..queries.len() {
                if threaded[q].to_bits() != batch[q].to_bits() {
                    return Err(format!("q={q}: threaded diverges"));
                }
            }
            Ok(())
        },
    );
}

/// The serving-tier construction path: a scorer rebuilt per micro-batch
/// from precomputed [`SupportInvariants`], scoring queries pushed into
/// one reused [`ScoreScratch`], is bit-identical to the owned
/// `Scorer::new` + `decision_values` pass — across kernels, uneven
/// batch splits and thread counts. This is the contract that lets
/// `pasmo serve` answer with the same bits as offline `pasmo predict`
/// while allocating nothing in its steady state.
#[test]
fn quickcheck_invariants_and_scratch_reuse_are_bit_identical() {
    forall(
        "serve-scratch-vs-owned",
        24,
        |g| {
            let d = 1 + g.below(8);
            let n_sv = 1 + g.below(60);
            let n_q = 1 + g.below(40);
            let sv = random_ds(n_sv, d, g);
            let coef: Vec<f64> = (0..n_sv).map(|_| g.normal() * 3.0).collect();
            let offset = g.normal();
            let queries = random_ds(n_q, d, g);
            let kernel = match g.below(4) {
                0 => KernelFunction::Rbf { gamma: g.range(0.05, 2.0) },
                1 => KernelFunction::Linear,
                2 => KernelFunction::Poly {
                    gamma: g.range(0.1, 1.0),
                    coef0: 1.0,
                    degree: 2 + g.below(3) as u32,
                },
                _ => KernelFunction::Sigmoid { gamma: g.range(0.05, 0.5), coef0: 0.1 },
            };
            (kernel, sv, coef, offset, queries)
        },
        |(kernel, sv, coef, offset, queries)| {
            let want = Scorer::new(*kernel, sv, coef, *offset).decision_values(queries);
            let inv = SupportInvariants::compute(*kernel, sv, coef);
            let mut scratch = ScoreScratch::new();
            let mut got = Vec::new();
            // Replay the stream in uneven micro-batches (1, 3, 5, …),
            // rebuilding the scorer per batch exactly as the serving
            // loop does, alternating thread counts along the way.
            let (mut q, mut step) = (0usize, 1usize);
            while q < queries.len() {
                let n = step.min(queries.len() - q);
                scratch.reset(queries.dim());
                for i in q..q + n {
                    scratch.push(queries.row(i));
                }
                let scorer = Scorer::with_invariants(*kernel, sv, coef, *offset, &inv)
                    .with_threads(1 + (step / 2) % 3);
                got.extend_from_slice(scorer.decision_scratch(&mut scratch));
                q += n;
                step += 2;
            }
            for i in 0..queries.len() {
                if got[i].to_bits() != want[i].to_bits() {
                    return Err(format!(
                        "q={i}: scratch {} != owned {} (bitwise)",
                        got[i], want[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// A trained classifier: scorer-backed decision/predict/evaluate agree
/// with the legacy loop over its own expansion, threads included.
#[test]
fn trained_svc_batch_parity_and_threads() {
    let data = Arc::new(pasmo::data::synth::chessboard(250, 4, 11));
    let model = Trainer::rbf(10.0, 0.5).train(&data).model;
    let ev1 = predict::evaluate(&model, &data, 1);
    let ev4 = predict::evaluate(&model, &data, 4);
    for i in 0..data.len() {
        let want = legacy_decision(
            model.kernel,
            &model.support,
            &model.coef,
            model.bias,
            data.row(i),
        );
        assert!(
            (ev1.decisions[i] - want).abs() <= tol(&model.coef, want),
            "i={i}: {} vs {want}",
            ev1.decisions[i]
        );
        assert_eq!(ev1.decisions[i].to_bits(), ev4.decisions[i].to_bits(), "i={i} threads");
    }
    assert_eq!(ev1.predictions, predict::predict_all(&model, &data));
    assert_eq!(ev1.accuracy, predict::accuracy(&model, &data));
    assert_eq!(ev1.confusion, predict::confusion(&model, &data));
}

/// SVR: batch predictions match the legacy loop; v2 `svr` schema round
/// trips exactly (f32 features and f64 coefficients survive JSON).
#[test]
fn svr_parity_and_schema_round_trip() {
    let train = sinc(150, 0.05, 12);
    let (model, _) = train_svr_native(&train, &SvrConfig::new(5.0, 0.05, 0.5));
    let test = sinc(70, 0.0, 13);
    let batch = model.predict_all(&test, 1);
    let threaded = model.predict_all(&test, 4);
    for i in 0..test.len() {
        let want = legacy_decision(
            model.kernel,
            &model.support,
            &model.coef,
            model.bias,
            test.row(i),
        );
        assert!((batch[i] - want).abs() <= tol(&model.coef, want), "i={i}");
        assert_eq!(batch[i].to_bits(), threaded[i].to_bits(), "i={i} threads");
    }
    let path = temp_path("svr.json");
    model.save(&path).unwrap();
    let loaded = SvrModel::load(&path).unwrap();
    assert_eq!(loaded.n_sv(), model.n_sv());
    let reloaded = loaded.predict_all(&test, 1);
    for i in 0..test.len() {
        assert!((reloaded[i] - batch[i]).abs() < 1e-9, "i={i}");
    }
    std::fs::remove_file(&path).ok();
}

/// One-class: batch decisions match the legacy loop (offset −ρ); v2
/// `oneclass` schema round trips.
#[test]
fn oneclass_parity_and_schema_round_trip() {
    let mut rng = Pcg::new(14);
    let ds = Arc::new(random_ds(180, 2, &mut rng));
    let (model, _) = train_one_class(&ds, &OneClassConfig::new(0.15, 0.4));
    let queries = random_ds(60, 2, &mut rng);
    let batch = model.decision_values(&queries, 1);
    let threaded = model.decision_values(&queries, 4);
    for i in 0..queries.len() {
        let want = legacy_decision(
            model.kernel,
            &model.support,
            &model.coef,
            -model.rho,
            queries.row(i),
        );
        assert!((batch[i] - want).abs() <= tol(&model.coef, want), "i={i}");
        assert_eq!(batch[i].to_bits(), threaded[i].to_bits(), "i={i} threads");
        assert_eq!(model.is_inlier(queries.row(i)), batch[i] >= 0.0, "i={i}");
    }
    let path = temp_path("oneclass.json");
    model.save(&path).unwrap();
    let loaded = OneClassModel::load(&path).unwrap();
    assert_eq!(loaded.n_sv(), model.n_sv());
    for i in 0..queries.len() {
        let d = (loaded.decision(queries.row(i)) - batch[i]).abs();
        assert!(d < 1e-9, "i={i}: Δ={d}");
    }
    std::fs::remove_file(&path).ok();
}

/// Multiclass: batch voting equals per-example voting; v2 `multiclass`
/// schema round trips machines, pairs and classes.
#[test]
fn multiclass_parity_and_schema_round_trip() {
    let train = blobs(180, 3, 5.0, 0.4, 15);
    let test = blobs(90, 3, 5.0, 0.4, 16);
    let model = train_ovo(&train, &Trainer::rbf(10.0, 0.3));
    let batch = model.predict_all(&test, 1);
    let threaded = model.predict_all(&test, 4);
    for i in 0..test.len() {
        assert_eq!(batch[i], model.predict(test.row(i)), "i={i}");
        assert_eq!(batch[i], threaded[i], "i={i} threads");
    }
    let path = temp_path("ovo.json");
    model.save(&path).unwrap();
    let loaded = OvoModel::load(&path).unwrap();
    assert_eq!(loaded.classes, model.classes);
    assert_eq!(loaded.pairs(), model.pairs());
    assert_eq!(loaded.machines.len(), model.machines.len());
    assert_eq!(loaded.predict_all(&test, 1), batch);
    std::fs::remove_file(&path).ok();
}

/// Cross-kind loads fail with a clear kind message instead of parsing
/// garbage, and kind-specific loaders reject other kinds.
#[test]
fn kind_tags_are_enforced_on_load() {
    let train = sinc(60, 0.05, 17);
    let (svr, _) = train_svr_native(&train, &SvrConfig::new(2.0, 0.1, 0.5));
    let path = temp_path("kind-mismatch.json");
    svr.save(&path).unwrap();
    let err = SvmModel::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("svr"), "{err:#}");
    let err = OneClassModel::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("svr"), "{err:#}");
    let err = OvoModel::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("svr"), "{err:#}");
    assert!(SvrModel::load(&path).is_ok());
    std::fs::remove_file(&path).ok();
}

/// Strict parsing: a non-numeric coefficient in any kind's document
/// fails with its position (the v1 loader silently dropped it).
#[test]
fn malformed_documents_fail_with_positions() {
    let path = temp_path("bad-svr.json");
    std::fs::write(
        &path,
        "{\"kind\":\"svr\",\"kernel\":\"rbf\",\"gamma\":0.5,\"coef0\":0,\
         \"degree\":0,\"bias\":0,\"dim\":1,\"coef\":[1.0,true],\
         \"sv\":[[1],[2]]}",
    )
    .unwrap();
    let err = SvrModel::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("coef[1]"), "{err:#}");
    std::fs::remove_file(&path).ok();
}

/// The SIMD wall, end to end: forced-SIMD and forced-scalar scoring
/// passes are `to_bits`-identical for all four kernels (expansion path,
/// so the tile runs for linear too), for a trained model, and for CSR
/// queries (which must keep taking the merged-dot fallback under both
/// modes). Skipped where AVX2 is absent — there is only one tile there.
#[test]
fn simd_off_and_force_scoring_passes_are_bit_identical() {
    use pasmo::kernel::tile::simd::{self, SimdMode};
    if !simd::simd_supported() {
        return;
    }
    let mut rng = Pcg::new(0x51D);
    let sv = random_ds(120, 19, &mut rng);
    let coef: Vec<f64> = (0..sv.len()).map(|_| rng.normal()).collect();
    let queries = random_ds(64, 19, &mut rng);
    let kernels = [
        KernelFunction::Rbf { gamma: 0.4 },
        KernelFunction::Linear,
        KernelFunction::Poly { gamma: 0.3, coef0: 1.0, degree: 3 },
        KernelFunction::Sigmoid { gamma: 0.2, coef0: 0.1 },
    ];
    for kernel in kernels {
        let scorer = Scorer::new(kernel, &sv, &coef, 0.25).collapse_linear(false);
        assert!(simd::set_simd_mode(SimdMode::Off));
        let want = scorer.decision_values(&queries);
        assert!(simd::set_simd_mode(SimdMode::Force));
        let got = scorer.decision_values(&queries);
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.to_bits(), g.to_bits(), "{kernel:?}: SIMD pass diverged");
        }
    }

    let data = Arc::new(pasmo::data::synth::chessboard(160, 4, 3));
    let model = Trainer::rbf(10.0, 0.5).train(&data).model;
    let dense_q = pasmo::data::synth::chessboard(80, 4, 4);
    let sparse_q = dense_q.to_sparse();
    let scorer = Scorer::new(model.kernel, &model.support, &model.coef, model.bias);
    assert!(simd::set_simd_mode(SimdMode::Off));
    let want_dense = scorer.decision_values(&dense_q);
    let want_sparse = scorer.decision_values(&sparse_q);
    assert!(simd::set_simd_mode(SimdMode::Force));
    let got_dense = scorer.decision_values(&dense_q);
    let got_sparse = scorer.decision_values(&sparse_q);
    for (w, g) in want_dense.iter().zip(&got_dense) {
        assert_eq!(w.to_bits(), g.to_bits(), "trained-model SIMD pass diverged");
    }
    for (w, g) in want_sparse.iter().zip(&got_sparse) {
        assert_eq!(w.to_bits(), g.to_bits(), "CSR fallback must not depend on the mode");
    }

    // restore the ambient selection for the rest of this binary
    let ambient = std::env::var("PASMO_SIMD")
        .ok()
        .and_then(|v| SimdMode::parse(&v))
        .unwrap_or(SimdMode::Auto);
    assert!(simd::set_simd_mode(ambient));
}
