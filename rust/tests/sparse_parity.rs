//! Sparse↔dense parity wall: the CSR feature backend must be an
//! *arithmetic no-op*. A dataset stored sparse and its densified twin
//! hold the same numbers, so everything downstream — gram rows, solver
//! trajectories, trained models, batch scoring — must agree: bit-for-bit
//! for the dot-product kernels (whose sparse dot skips only exact-zero
//! terms of the same ascending-order accumulation), and to ≤1e-12
//! relative for RBF (both backends share the ‖a‖²+‖b‖²−2a·b
//! decomposition, so in practice this is bitwise too; the tolerance is
//! the contract, not the observation).
//!
//! Mirrors `tests/predict_parity.rs`: `forall` quickcheck over random
//! problems, engines × shrinking × warm-start for training, all four
//! kernels, thread-count bit-determinism on the sparse path.

use std::sync::Arc;

use pasmo::data::dataset::Dataset;
use pasmo::data::synth::sparse_blobs;
use pasmo::kernel::KernelFunction;
use pasmo::solver::SolverChoice;
use pasmo::svm::scorer::Scorer;
use pasmo::svm::Trainer;
use pasmo::util::prng::Pcg;
use pasmo::util::quickcheck::forall;

/// The ≤1e-12 agreement bound used for the RBF legs, scaled like
/// `predict_parity::tol` by the expansion's ℓ1 mass.
fn tol(coef: &[f64], want: f64) -> f64 {
    1e-12 * (1.0 + want.abs() + coef.iter().map(|c| c.abs()).sum::<f64>())
}

/// A dense dataset with ~`p_zero` of its coordinates exactly 0.0 (the
/// regime where CSR stores less), plus its CSR twin. Labels alternate so
/// every draw is a valid two-class problem.
fn twin_pair(g: &mut Pcg, n: usize, d: usize, p_zero: f64) -> (Arc<Dataset>, Arc<Dataset>) {
    let mut ds = Dataset::with_dim(d);
    let mut row = vec![0f32; d];
    for i in 0..n {
        for v in row.iter_mut() {
            *v = if g.bernoulli(p_zero) { 0.0 } else { g.normal() as f32 };
        }
        ds.push(&row, if i % 2 == 0 { 1 } else { -1 });
    }
    let sparse = Arc::new(ds.to_sparse());
    (Arc::new(ds), sparse)
}

fn random_kernel(g: &mut Pcg) -> KernelFunction {
    match g.below(4) {
        0 => KernelFunction::Rbf { gamma: g.range(0.05, 2.0) },
        1 => KernelFunction::Linear,
        2 => KernelFunction::Poly {
            gamma: g.range(0.1, 1.0),
            coef0: 1.0,
            degree: 2 + g.below(3) as u32,
        },
        _ => KernelFunction::Sigmoid { gamma: g.range(0.05, 0.5), coef0: 0.1 },
    }
}

fn is_rbf(k: &KernelFunction) -> bool {
    matches!(k, KernelFunction::Rbf { .. })
}

/// Elementwise comparison of two solver/model coefficient vectors:
/// bitwise unless `loose` (the RBF contract), which allows ≤1e-12.
fn compare_vecs(tag: &str, got: &[f64], want: &[f64], loose: bool) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{tag}: length {} != {}", got.len(), want.len()));
    }
    for (i, (&a, &b)) in got.iter().zip(want).enumerate() {
        if !loose && a.to_bits() != b.to_bits() {
            return Err(format!("{tag}[{i}]: {a} != {b} (bitwise)"));
        }
        if (a - b).abs() > 1e-12 * (1.0 + b.abs()) {
            return Err(format!("{tag}[{i}]: {a} vs {b}"));
        }
    }
    Ok(())
}

/// Training parity: the same trainer over a CSR dataset and its dense
/// twin walks the same solver trajectory — across all three engines,
/// shrinking on and off, all four kernels.
#[test]
fn quickcheck_training_parity_across_engines_and_shrinking() {
    forall(
        "sparse-train-vs-dense-train",
        10,
        |g| {
            let n = 20 + g.below(40);
            let d = 3 + g.below(10);
            let (dense, sparse) = twin_pair(g, n, d, 0.7);
            let kernel = random_kernel(g);
            let c = g.range(0.5, 20.0);
            (dense, sparse, kernel, c)
        },
        |(dense, sparse, kernel, c)| {
            let loose = is_rbf(kernel);
            for solver in [SolverChoice::Smo, SolverChoice::Pasmo, SolverChoice::ConjugateSmo] {
                for shrinking in [false, true] {
                    let trainer = {
                        let mut t = Trainer::new(*kernel).c(*c).solver(solver);
                        t.solver_config.shrinking = shrinking;
                        t
                    };
                    let on_dense = trainer.train(dense);
                    let on_sparse = trainer.train(sparse);
                    let tag = format!("{solver:?} shrink={shrinking}");
                    compare_vecs(
                        &format!("{tag} alpha"),
                        &on_sparse.result.alpha,
                        &on_dense.result.alpha,
                        loose,
                    )?;
                    if !loose {
                        if on_sparse.result.iterations != on_dense.result.iterations {
                            return Err(format!(
                                "{tag}: {} iterations vs {}",
                                on_sparse.result.iterations, on_dense.result.iterations
                            ));
                        }
                        if on_sparse.model.bias.to_bits() != on_dense.model.bias.to_bits() {
                            return Err(format!(
                                "{tag} bias: {} != {} (bitwise)",
                                on_sparse.model.bias, on_dense.model.bias
                            ));
                        }
                    }
                    compare_vecs(
                        &format!("{tag} coef"),
                        &on_sparse.model.coef,
                        &on_dense.model.coef,
                        loose,
                    )?;
                    // The extracted support keeps its backend but holds
                    // the same numbers.
                    if !on_sparse.model.support.is_sparse() {
                        return Err(format!("{tag}: sparse support was densified"));
                    }
                    if on_sparse.model.support.to_dense() != on_dense.model.support.to_dense() {
                        return Err(format!("{tag}: support vectors differ"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Warm starts cross the backend boundary: α from a dense solve seeds a
/// sparse re-solve (and vice versa) exactly like a same-backend restart.
#[test]
fn quickcheck_warm_start_crosses_backends() {
    forall(
        "sparse-warm-start",
        8,
        |g| {
            let n = 24 + g.below(36);
            let d = 3 + g.below(8);
            let (dense, sparse) = twin_pair(g, n, d, 0.7);
            let kernel = random_kernel(g);
            (dense, sparse, kernel)
        },
        |(dense, sparse, kernel)| {
            let loose = is_rbf(kernel);
            let cold = Trainer::new(*kernel).c(5.0).train(dense);
            let warm_dense =
                Trainer::new(*kernel).c(5.0).warm_start(cold.result.alpha.clone()).train(dense);
            let warm_sparse =
                Trainer::new(*kernel).c(5.0).warm_start(cold.result.alpha.clone()).train(sparse);
            compare_vecs(
                "warm alpha",
                &warm_sparse.result.alpha,
                &warm_dense.result.alpha,
                loose,
            )?;
            if !loose && warm_sparse.result.iterations != warm_dense.result.iterations {
                return Err(format!(
                    "warm iterations: {} vs {}",
                    warm_sparse.result.iterations, warm_dense.result.iterations
                ));
            }
            Ok(())
        },
    );
}

/// Scoring parity: a fixed random expansion scored over every
/// sparse/dense combination of support set and query set agrees with the
/// all-dense reference — bitwise for the dot kernels (collapse disabled
/// so both sides run the expansion), ≤1e-12 for RBF — and the sparse
/// legs stay bit-identical across thread counts.
#[test]
fn quickcheck_scoring_parity_across_backends() {
    forall(
        "sparse-score-vs-dense-score",
        20,
        |g| {
            let d = 2 + g.below(10);
            let n_sv = 1 + g.below(50);
            let n_q = 1 + g.below(40);
            let (sv_dense, sv_sparse) = twin_pair(g, n_sv, d, 0.6);
            let (q_dense, q_sparse) = twin_pair(g, n_q, d, 0.6);
            let coef: Vec<f64> = (0..n_sv).map(|_| g.normal() * 3.0).collect();
            let offset = g.normal();
            let kernel = random_kernel(g);
            (sv_dense, sv_sparse, q_dense, q_sparse, coef, offset, kernel)
        },
        |(sv_dense, sv_sparse, q_dense, q_sparse, coef, offset, kernel)| {
            let loose = is_rbf(kernel);
            let want = Scorer::new(*kernel, sv_dense, coef, *offset)
                .collapse_linear(false)
                .decision_values(q_dense);
            for (tag, sv, q) in [
                ("dense-sv/sparse-q", sv_dense, q_sparse),
                ("sparse-sv/dense-q", sv_sparse, q_dense),
                ("sparse-sv/sparse-q", sv_sparse, q_sparse),
            ] {
                let got = Scorer::new(*kernel, sv, coef, *offset)
                    .collapse_linear(false)
                    .decision_values(q);
                for i in 0..want.len() {
                    if !loose && got[i].to_bits() != want[i].to_bits() {
                        return Err(format!("{tag} q={i}: {} != {} (bitwise)", got[i], want[i]));
                    }
                    if (got[i] - want[i]).abs() > tol(coef, want[i]) {
                        return Err(format!("{tag} q={i}: {} vs {}", got[i], want[i]));
                    }
                }
                let threaded = Scorer::new(*kernel, sv, coef, *offset)
                    .collapse_linear(false)
                    .with_threads(4)
                    .decision_values(q);
                for i in 0..want.len() {
                    if threaded[i].to_bits() != got[i].to_bits() {
                        return Err(format!("{tag} q={i}: threaded diverges"));
                    }
                }
            }
            // Default construction (collapse heuristics enabled) stays
            // within the tolerance contract even when only one side
            // collapses its linear expansion.
            let def_want = Scorer::new(*kernel, sv_dense, coef, *offset).decision_values(q_dense);
            let def_got = Scorer::new(*kernel, sv_sparse, coef, *offset).decision_values(q_sparse);
            for i in 0..def_want.len() {
                if (def_got[i] - def_want[i]).abs() > tol(coef, def_want[i]) {
                    return Err(format!(
                        "default q={i}: {} vs {}",
                        def_got[i], def_want[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// An end-to-end leg on the generator the CLI bench uses: train on a
/// genuinely sparse dataset, score it, and check the whole pipeline
/// against the densified twin — plus trainer thread invariance on CSR.
#[test]
fn sparse_blobs_train_and_score_match_densified_twin() {
    let sparse = Arc::new(sparse_blobs(160, 400, 6, 21));
    let dense = Arc::new(sparse.to_dense());
    assert!(sparse.is_sparse() && !dense.is_sparse());
    assert!(sparse.resident_bytes() < dense.resident_bytes());

    for (kernel, loose) in [
        (KernelFunction::Linear, false),
        (KernelFunction::Rbf { gamma: 0.5 }, true),
    ] {
        let trainer = Trainer::new(kernel).c(2.0);
        let on_sparse = trainer.train(&sparse);
        let on_dense = trainer.train(&dense);
        compare_vecs("alpha", &on_sparse.result.alpha, &on_dense.result.alpha, loose).unwrap();

        let got = on_sparse.model.scorer().decision_values(&sparse);
        let want = on_dense.model.scorer().decision_values(&dense);
        for i in 0..want.len() {
            assert!(
                (got[i] - want[i]).abs() <= tol(&on_dense.model.coef, want[i]),
                "{kernel:?} i={i}: {} vs {}",
                got[i],
                want[i]
            );
        }

        // Thread count never changes the bits, dense or sparse.
        let threaded = trainer.clone().threads(4).train(&sparse);
        assert_eq!(threaded.result.alpha, on_sparse.result.alpha, "{kernel:?} threads");
    }
}

/// Subset/permutation plumbing (the cross-validation path) preserves the
/// backend and the numbers.
#[test]
fn subset_and_permuted_preserve_backend_and_values() {
    let sparse = sparse_blobs(60, 120, 4, 5);
    let dense = sparse.to_dense();
    let idx: Vec<usize> = (0..60).filter(|i| i % 3 != 0).collect();
    let perm: Vec<usize> = (0..60).map(|i| (i * 7) % 60).collect();

    let sub_s = sparse.subset(&idx);
    let sub_d = dense.subset(&idx);
    assert!(sub_s.is_sparse() && !sub_d.is_sparse());
    assert_eq!(sub_s.to_dense(), sub_d);

    let perm_s = sparse.permuted(&perm);
    let perm_d = dense.permuted(&perm);
    assert!(perm_s.is_sparse());
    assert_eq!(perm_s.to_dense(), perm_d);
}
