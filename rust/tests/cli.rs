//! CLI integration tests: drive the `pasmo` binary end to end.

use std::path::{Path, PathBuf};
use std::process::Command;

fn pasmo() -> Command {
    Command::new(env!("CARGO_BIN_EXE_pasmo"))
}

/// A per-test scratch directory, unique per (test, process) and removed
/// when the test ends — stale model files from a previous or concurrent
/// run can never mask a failure.
struct TempDir(PathBuf);

impl TempDir {
    fn new(test: &str) -> TempDir {
        let dir = std::env::temp_dir().join(format!(
            "pasmo-cli-{test}-{}",
            std::process::id()
        ));
        // A leftover directory (e.g. from a killed run with the same pid)
        // is wiped so every test starts from a clean slate.
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }

    fn path(&self, name: &str) -> PathBuf {
        self.0.join(name)
    }
}

impl AsRef<Path> for TempDir {
    fn as_ref(&self) -> &Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.0).ok();
    }
}

#[test]
fn no_args_prints_usage() {
    let out = pasmo().output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("usage: pasmo"));
    assert!(text.contains("experiment"));
}

/// Help/flag parity: every `--flag` the binary reads (extracted from
/// `src/main.rs` by scanning the `Args` accessor calls) must appear in
/// the help output of `pasmo --help` + every subcommand's `--help`.
/// A flag added to the code without a help line fails this test.
#[test]
fn help_documents_every_flag_the_code_reads() {
    const SUBCOMMANDS: [&str; 9] = [
        "datasets",
        "train",
        "predict",
        "gridsearch",
        "bench",
        "experiment",
        "serve",
        "audit",
        "info",
    ];
    // 1. Collect the full help corpus.
    let mut corpus = String::new();
    let general = pasmo().arg("--help").output().unwrap();
    assert!(general.status.success());
    corpus.push_str(&String::from_utf8_lossy(&general.stdout));
    for cmd in SUBCOMMANDS {
        let out = pasmo().args([cmd, "--help"]).output().unwrap();
        assert!(out.status.success(), "{cmd} --help failed");
        let text = String::from_utf8_lossy(&out.stdout).to_string();
        assert!(
            text.contains(&format!("pasmo {cmd}")),
            "{cmd} --help does not name its command:\n{text}"
        );
        // `pasmo help <cmd>` must print the same page.
        let via_help = pasmo().args(["help", cmd]).output().unwrap();
        assert_eq!(text, String::from_utf8_lossy(&via_help.stdout).to_string());
        corpus.push_str(&text);
    }
    // 2. Extract every flag name read anywhere in main.rs.
    let src = include_str!("../src/main.rs");
    let mut flags = std::collections::BTreeSet::new();
    for pat in ["args.get(\"", "args.get_or(\"", "args.get_parse_or(\"", "args.flag(\""] {
        for (idx, _) in src.match_indices(pat) {
            let rest = &src[idx + pat.len()..];
            let name = &rest[..rest.find('"').unwrap()];
            flags.insert(name.to_string());
        }
    }
    assert!(flags.len() >= 20, "flag extraction looks broken: {flags:?}");
    for required in ["threads", "w-pos", "w-neg", "cold", "solver", "help"] {
        assert!(flags.contains(required), "expected to extract --{required}");
    }
    // 3. Every flag appears as `--name` followed by a non-name character.
    for flag in &flags {
        let needle = format!("--{flag}");
        let documented = corpus.match_indices(&needle).any(|(i, _)| {
            corpus[i + needle.len()..]
                .chars()
                .next()
                .map(|c| !(c.is_ascii_alphanumeric() || c == '-'))
                .unwrap_or(true)
        });
        assert!(documented, "flag --{flag} is read by main.rs but not documented in any help text");
    }
    // 4. The solver flag documents every engine, including the new one.
    for solver in ["smo", "pasmo", "pasmo-multi:N", "conjugate"] {
        assert!(
            corpus.contains(solver),
            "help does not list solver value {solver:?}"
        );
    }
}

/// `pasmo audit` on a fixture tree: violations exit nonzero and are
/// reported; a matching allowlist turns the same tree green; a stale
/// allowlist entry flips it red again.
#[test]
fn audit_flags_fixture_violations_and_honours_the_allowlist() {
    let dir = TempDir::new("audit-fixture");
    let src = dir.path("src");
    std::fs::create_dir_all(&src).unwrap();
    std::fs::write(
        src.join("bad.rs"),
        "pub fn f(v: Option<u8>) -> u8 {\n    v.unwrap()\n}\n",
    )
    .unwrap();

    // 1. Violation with no allowlist: nonzero exit, rule named in output.
    let out = pasmo()
        .args(["audit", "--src"])
        .arg(&src)
        .args(["--allowlist"])
        .arg(dir.path("missing.allow"))
        .output()
        .unwrap();
    assert!(!out.status.success(), "audit passed a tree with .unwrap()");
    let text = String::from_utf8_lossy(&out.stdout).to_string()
        + &String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("no-panic"), "rule missing from report:\n{text}");
    assert!(text.contains("bad.rs"), "file missing from report:\n{text}");

    // 2. An exact-content allowlist entry excuses it.
    let allow = dir.path("audit.allow");
    std::fs::write(&allow, "bad.rs:no-panic:v.unwrap()\n").unwrap();
    let out = pasmo()
        .args(["audit", "--src"])
        .arg(&src)
        .args(["--allowlist"])
        .arg(&allow)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "allowlisted tree still fails: {}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    // 3. A stale entry (fixed code, lingering excuse) is itself an error.
    std::fs::write(src.join("bad.rs"), "pub fn f(v: Option<u8>) -> u8 {\n    v.unwrap_or(0)\n}\n")
        .unwrap();
    let out = pasmo()
        .args(["audit", "--src"])
        .arg(&src)
        .args(["--allowlist"])
        .arg(&allow)
        .output()
        .unwrap();
    assert!(!out.status.success(), "stale allowlist entry went unnoticed");
    let text = String::from_utf8_lossy(&out.stdout).to_string()
        + &String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("stale-allow"), "stale rule missing:\n{text}");
}

#[test]
fn datasets_lists_the_suite() {
    let out = pasmo().arg("datasets").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for name in ["banana", "chess-board-100000", "spam-database"] {
        assert!(text.contains(name), "{name} missing from:\n{text}");
    }
}

#[test]
fn train_save_predict_round_trip() {
    let dir = TempDir::new("train-save-predict");
    let model = dir.path("model.json");
    let out = pasmo()
        .args([
            "train", "--dataset", "chess-board-1000", "--len", "300", "--solver",
            "pasmo", "--out",
        ])
        .arg(&model)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("converged=true"), "{text}");
    assert!(model.exists());

    // write a small libsvm test file from the same generator family
    let test_path = dir.path("test.libsvm");
    let ds = pasmo::data::synth::chessboard(100, 4, 99);
    pasmo::data::libsvm::write(&ds, &test_path).unwrap();

    let out = pasmo()
        .args(["predict", "--model"])
        .arg(&model)
        .args(["--libsvm"])
        .arg(&test_path)
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("accuracy"), "{text}");
    // parse the accuracy (first line; confusion counts follow) and
    // demand something sane
    let acc: f64 = text
        .split("accuracy = ")
        .nth(1)
        .unwrap()
        .lines()
        .next()
        .unwrap()
        .trim()
        .parse()
        .unwrap();
    assert!(acc > 0.8, "accuracy {acc}");
}

#[test]
fn experiment_fig2_writes_report() {
    let dir = TempDir::new("experiment-fig2");
    let report = dir.path("fig2.md");
    let out = pasmo()
        .args(["experiment", "fig2", "--out"])
        .arg(&report)
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = std::fs::read_to_string(&report).unwrap();
    assert!(text.contains("Figure 2"));
    assert!(text.contains("η-band"));
}

#[test]
fn unknown_experiment_fails_cleanly() {
    let out = pasmo().args(["experiment", "nope"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown experiment"));
}

#[test]
fn train_accepts_per_class_cost_weights() {
    let out = pasmo()
        .args([
            "train", "--dataset", "banana", "--len", "200", "--w-pos", "4", "--w-neg", "1",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "weighted train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("converged=true"), "{text}");
}

#[test]
fn train_accepts_conjugate_solver() {
    let out = pasmo()
        .args([
            "train", "--dataset", "chess-board-1000", "--len", "300", "--solver", "conjugate",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "conjugate train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("converged=true"), "{text}");
    assert!(text.contains("solver=ConjugateSmo"), "{text}");
}

#[test]
fn train_rejects_unknown_solver() {
    let out = pasmo()
        .args(["train", "--dataset", "banana", "--len", "100", "--solver", "sgd"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown solver"), "{err}");
    assert!(err.contains("conjugate"), "error should list the valid engines: {err}");
}

#[test]
fn experiment_engine_shootout_runs_three_engines() {
    let dir = TempDir::new("engine-shootout");
    let report = dir.path("shootout.md");
    let out = pasmo()
        .args([
            "experiment",
            "engine_shootout",
            "--datasets",
            "thyroid",
            "--perms",
            "3",
            "--max-len",
            "120",
            "--out",
        ])
        .arg(&report)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "engine_shootout failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = std::fs::read_to_string(&report).unwrap();
    assert!(text.contains("Engine shootout"), "{text}");
    assert!(text.contains("Conjugate SMO"), "{text}");
    assert!(text.contains("iters CSMO"), "{text}");
    assert!(text.contains("thyroid"), "{text}");
}

#[test]
fn bench_accepts_conjugate_solver() {
    let dir = TempDir::new("bench-conjugate");
    let path = dir.path("BENCH_conjugate.json");
    let out = pasmo()
        .args([
            "bench",
            "--len",
            "300",
            "--datasets",
            "chess-board-1000",
            "--cache-rows",
            "32",
            "--shrink-interval",
            "50",
            "--solver",
            "conjugate",
            "--out",
        ])
        .arg(&path)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "conjugate bench failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let doc =
        pasmo::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let runs = doc.get("runs").unwrap().as_arr().unwrap();
    assert_eq!(runs.len(), 2, "conjugate × shrink on/off");
    for r in runs {
        assert_eq!(r.get("solver").unwrap().as_str(), Some("conjugate"));
        assert_eq!(r.get("converged").unwrap().as_bool(), Some(true));
    }
}

#[test]
fn train_accepts_threads_flag() {
    let out = pasmo()
        .args(["train", "--dataset", "chess-board-1000", "--len", "300", "--threads", "2"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "threaded train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("converged=true"));
}

#[test]
fn bench_writes_kernel_entry_trajectory_json() {
    let dir = TempDir::new("bench-json");
    let path = dir.path("BENCH_solver.json");
    let out = pasmo()
        .args([
            "bench",
            "--len",
            "300",
            "--datasets",
            "chess-board-1000",
            "--cache-rows",
            "32",
            "--shrink-interval",
            "50",
            "--out",
        ])
        .arg(&path)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "bench failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let doc =
        pasmo::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(doc.get("bench").unwrap().as_str(), Some("solver"));
    let runs = doc.get("runs").unwrap().as_arr().unwrap();
    assert_eq!(runs.len(), 4, "smo/pasmo × shrink on/off");
    for r in runs {
        assert_eq!(r.get("converged").unwrap().as_bool(), Some(true));
    }
    // The perf claim the artifact exists to track: with shrinking enabled
    // the solver computes strictly fewer kernel entries.
    for solver in ["smo", "pasmo"] {
        let entries = |shrink: bool| {
            runs.iter()
                .find(|r| {
                    r.get("solver").unwrap().as_str() == Some(solver)
                        && r.get("shrinking").unwrap().as_bool() == Some(shrink)
                })
                .unwrap()
                .get("kernel_entries")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert!(
            entries(true) < entries(false),
            "{solver}: shrink-on {} !< shrink-off {}",
            entries(true),
            entries(false)
        );
    }
}

#[test]
fn predict_accepts_task_threads_and_writes_predictions() {
    let dir = TempDir::new("predict-task");
    let model = dir.path("model.json");
    let out = pasmo()
        .args(["train", "--dataset", "banana", "--len", "250", "--out"])
        .arg(&model)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let test_path = dir.path("test.libsvm");
    let ds = pasmo::data::synth::banana(120, 99);
    pasmo::data::libsvm::write(&ds, &test_path).unwrap();

    let preds = dir.path("preds.txt");
    let out = pasmo()
        .args(["predict", "--model"])
        .arg(&model)
        .args(["--libsvm"])
        .arg(&test_path)
        .args(["--task", "classify", "--threads", "2", "--out"])
        .arg(&preds)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("accuracy"), "{text}");
    assert!(text.contains("confusion"), "{text}");
    let lines = std::fs::read_to_string(&preds).unwrap();
    assert_eq!(lines.lines().count(), 120, "one prediction per example");

    // a wrong --task is rejected with the model's actual kind
    let out = pasmo()
        .args(["predict", "--model"])
        .arg(&model)
        .args(["--libsvm"])
        .arg(&test_path)
        .args(["--task", "svr"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("classify"), "{err}");
}

#[test]
fn train_probability_enables_predict_probability() {
    let dir = TempDir::new("predict-probability");
    let model = dir.path("model.json");
    let out = pasmo()
        .args([
            "train", "--dataset", "banana", "--len", "250", "--probability", "--out",
        ])
        .arg(&model)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("Platt calibration"));
    assert!(std::fs::read_to_string(&model).unwrap().contains("\"platt\""));

    let test_path = dir.path("test.libsvm");
    let ds = pasmo::data::synth::banana(100, 7);
    pasmo::data::libsvm::write(&ds, &test_path).unwrap();

    let out = pasmo()
        .args(["predict", "--model"])
        .arg(&model)
        .args(["--libsvm"])
        .arg(&test_path)
        .args(["--probability"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("log-loss"), "{text}");
}

#[test]
fn predict_dispatches_svr_and_multiclass_model_files() {
    let dir = TempDir::new("predict-kinds");

    // SVR: save a model + a regression eval file through the library.
    let train = pasmo::data::regression::sinc(150, 0.05, 3);
    let (svr, _) = pasmo::svm::svr::train_svr_native(
        &train,
        &pasmo::svm::svr::SvrConfig::new(5.0, 0.05, 0.5),
    );
    let svr_path = dir.path("svr.json");
    svr.save(&svr_path).unwrap();
    let reg_path = dir.path("reg.libsvm");
    pasmo::data::libsvm::write_regression(&pasmo::data::regression::sinc(60, 0.0, 4), &reg_path)
        .unwrap();
    let out = pasmo()
        .args(["predict", "--model"])
        .arg(&svr_path)
        .args(["--libsvm"])
        .arg(&reg_path)
        .args(["--task", "svr"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("rmse"), "svr output");

    // Multiclass: one-vs-one model + class-labeled eval file.
    let mtrain = pasmo::data::multiclass::blobs(150, 3, 5.0, 0.4, 5);
    let ovo = pasmo::svm::multiclass::train_ovo(
        &mtrain,
        &pasmo::svm::Trainer::rbf(10.0, 0.3),
    );
    let ovo_path = dir.path("ovo.json");
    ovo.save(&ovo_path).unwrap();
    let multi_path = dir.path("multi.libsvm");
    pasmo::data::libsvm::write_multiclass(
        &pasmo::data::multiclass::blobs(80, 3, 5.0, 0.4, 6),
        &multi_path,
    )
    .unwrap();
    let out = pasmo()
        .args(["predict", "--model"])
        .arg(&ovo_path)
        .args(["--libsvm"])
        .arg(&multi_path)
        .args(["--task", "multiclass", "--threads", "2"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("3 classes") && text.contains("accuracy"), "{text}");

    // --probability is a classify-only flag: other kinds reject it
    // loudly instead of silently ignoring it.
    let out = pasmo()
        .args(["predict", "--model"])
        .arg(&ovo_path)
        .args(["--libsvm"])
        .arg(&multi_path)
        .args(["--probability"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("only available for classify"), "{err}");
}

#[test]
fn bench_predict_writes_throughput_json() {
    let dir = TempDir::new("bench-predict");
    let path = dir.path("BENCH_predict.json");
    let out = pasmo()
        .args([
            "bench",
            "--predict",
            "--len",
            "200",
            "--datasets",
            "chess-board-1000",
            "--threads",
            "2",
            "--out",
        ])
        .arg(&path)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "bench --predict failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let doc =
        pasmo::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(doc.get("bench").unwrap().as_str(), Some("predict"));
    let runs = doc.get("runs").unwrap().as_arr().unwrap();
    let modes: Vec<&str> =
        runs.iter().map(|r| r.get("mode").unwrap().as_str().unwrap()).collect();
    for mode in ["scalar", "tiled", "threaded", "linear", "linear-collapse"] {
        assert!(modes.contains(&mode), "missing mode {mode}: {modes:?}");
    }
    for r in runs {
        assert!(r.get("queries_per_s").unwrap().as_f64().unwrap() > 0.0);
    }
    // the linear collapse evaluates zero kernel entries
    let collapse = runs
        .iter()
        .find(|r| r.get("mode").unwrap().as_str() == Some("linear-collapse"))
        .unwrap();
    assert_eq!(
        collapse.get("kernel_entries_per_pass").unwrap().as_f64(),
        Some(0.0)
    );
}

#[test]
fn train_rejects_unknown_dataset() {
    let out = pasmo().args(["train", "--dataset", "bogus"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown dataset"));
}

#[test]
fn info_reports_environment() {
    let out = pasmo().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("pasmo 0.1.0"));
}

// ---------------------------------------------------------------------------
// `pasmo serve`: the micro-batching TCP inference tier, driven over real
// sockets against a real child process.
// ---------------------------------------------------------------------------

/// A `pasmo serve` child on an ephemeral port. The startup banner is
/// parsed for the bound address; the process is killed on drop so a
/// failing assertion can never leak a listening server.
struct ServeChild {
    child: std::process::Child,
    addr: String,
}

impl ServeChild {
    fn spawn(model_spec: &str, extra: &[&str]) -> ServeChild {
        use std::io::BufRead;
        let mut child = pasmo()
            .args(["serve", "--addr", "127.0.0.1:0", "--model", model_spec])
            .args(extra)
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::inherit())
            .spawn()
            .unwrap();
        let stdout = child.stdout.take().unwrap();
        let mut reader = std::io::BufReader::new(stdout);
        let mut banner = String::new();
        let mut addr = None;
        for _ in 0..64 {
            let mut line = String::new();
            if reader.read_line(&mut line).unwrap() == 0 {
                break;
            }
            banner.push_str(&line);
            if let Some(rest) = line.split("listening on ").nth(1) {
                addr = Some(rest.split_whitespace().next().unwrap().to_string());
                break;
            }
        }
        let Some(addr) = addr else {
            child.kill().ok();
            child.wait().ok();
            panic!("serve printed no listening banner:\n{banner}");
        };
        ServeChild { child, addr }
    }

    fn connect(&self) -> ServeConn {
        let stream = std::net::TcpStream::connect(&self.addr).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(30)))
            .unwrap();
        let reader = std::io::BufReader::new(stream.try_clone().unwrap());
        ServeConn { reader, writer: stream }
    }

    /// Request a clean shutdown and demand the child drains and exits 0.
    fn shutdown(mut self) {
        let reply = self.connect().roundtrip("{\"cmd\":\"shutdown\"}");
        assert!(reply.contains("\"shutting_down\":true"), "{reply}");
        let status = self.child.wait().unwrap();
        assert!(status.success(), "serve exited {status}");
    }
}

impl Drop for ServeChild {
    fn drop(&mut self) {
        self.child.kill().ok();
        self.child.wait().ok();
    }
}

/// One client connection: newline-delimited request/response pairs.
struct ServeConn {
    reader: std::io::BufReader<std::net::TcpStream>,
    writer: std::net::TcpStream,
}

impl ServeConn {
    fn send(&mut self, line: &str) {
        use std::io::Write;
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
    }

    fn recv(&mut self) -> String {
        use std::io::BufRead;
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "server closed the connection");
        reply.trim_end().to_string()
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.send(line);
        self.recv()
    }
}

/// Render a score request. Features go through `f32` `Display`
/// (shortest round-trip), so the server's f64-parse → f32-narrow
/// recovers the exact bits we started from.
fn score_line(model: Option<&str>, x: &[f32], id: usize) -> String {
    let mut s = String::from("{");
    if let Some(m) = model {
        s.push_str(&format!("\"model\":\"{m}\","));
    }
    s.push_str("\"x\":[");
    for (i, v) in x.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("{v}"));
    }
    s.push_str(&format!("],\"id\":{id}}}"));
    s
}

fn parse_reply(line: &str) -> pasmo::util::json::Json {
    pasmo::util::json::Json::parse(line)
        .unwrap_or_else(|e| panic!("bad reply {line:?}: {e:#}"))
}

/// The tentpole acceptance contract: every decision value served over
/// the socket is bit-identical to the same query through offline
/// `pasmo predict --out`. A burst of pipelined queries exercises the
/// admission micro-batcher (stats confirm multi-query batches) without
/// changing a single bit.
#[test]
fn serve_decisions_bit_match_offline_predict() {
    use pasmo::util::json::Json;
    let dir = TempDir::new("serve-parity");

    // Train a model through the CLI, exactly as a user would.
    let model_path = dir.path("model.json");
    let out = pasmo()
        .args(["train", "--dataset", "chess-board-1000", "--len", "300", "--out"])
        .arg(&model_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // Offline half: `pasmo predict --out` writes full-precision
    // decisions (prediction + shortest-round-trip decision per line).
    let queries = pasmo::data::synth::chessboard(60, 4, 99);
    let test_path = dir.path("test.libsvm");
    pasmo::data::libsvm::write(&queries, &test_path).unwrap();
    let preds_path = dir.path("preds.txt");
    let out = pasmo()
        .args(["predict", "--model"])
        .arg(&model_path)
        .args(["--libsvm"])
        .arg(&test_path)
        .args(["--out"])
        .arg(&preds_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let offline: Vec<(i32, f64)> = std::fs::read_to_string(&preds_path)
        .unwrap()
        .lines()
        .map(|l| {
            let mut it = l.split_whitespace();
            (
                it.next().unwrap().parse().unwrap(),
                it.next().unwrap().parse().unwrap(),
            )
        })
        .collect();
    assert_eq!(offline.len(), queries.len());

    // Online half: one pipelined burst through a small admission window
    // so queries actually coalesce into micro-batches.
    let server = ServeChild::spawn(
        &format!("m={}", model_path.display()),
        &["--max-batch", "16", "--max-wait-us", "500"],
    );
    let mut conn = server.connect();
    for i in 0..queries.len() {
        // a model-less query is legal while exactly one model is loaded
        conn.send(&score_line(None, queries.row(i), i));
    }
    for (i, &(pred, decision)) in offline.iter().enumerate() {
        let v = parse_reply(&conn.recv());
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "query {i}");
        assert_eq!(v.get("id").and_then(Json::as_f64), Some(i as f64), "reply order");
        assert_eq!(v.get("model").and_then(Json::as_str), Some("m"));
        assert_eq!(v.get("kind").and_then(Json::as_str), Some("classify"));
        assert_eq!(
            v.get("prediction").and_then(Json::as_f64),
            Some(pred as f64),
            "query {i}"
        );
        let served = v.get("decision").and_then(Json::as_f64).unwrap();
        assert_eq!(
            served.to_bits(),
            decision.to_bits(),
            "query {i}: served {served} != offline {decision}"
        );
    }

    // The burst actually micro-batched: 60 requests, fewer batches.
    let stats = parse_reply(&conn.roundtrip("{\"cmd\":\"stats\"}"));
    let m = stats.get("models").and_then(|v| v.get("m")).unwrap();
    assert_eq!(m.get("requests").and_then(Json::as_f64), Some(queries.len() as f64));
    let batches = m.get("batches").and_then(Json::as_f64).unwrap();
    assert!(
        batches >= 1.0 && batches < queries.len() as f64,
        "expected micro-batching: {batches} batches for {} requests",
        queries.len()
    );
    server.shutdown();
}

/// Multi-model routing, every error path, and hot-swap — all over one
/// live socket, with expectations computed from the same model files
/// through the library.
#[test]
fn serve_routes_models_rejects_bad_input_and_hot_swaps() {
    use pasmo::util::json::Json;
    let dir = TempDir::new("serve-routing");

    // Three model kinds, saved through the library.
    let train = std::sync::Arc::new(pasmo::data::synth::chessboard(200, 4, 21));
    let svc = pasmo::svm::Trainer::rbf(100.0, 0.5).train(&train).model;
    let svc_path = dir.path("svc.json");
    svc.save(&svc_path).unwrap();

    let (oc, _) = pasmo::svm::oneclass::train_one_class(
        &train,
        &pasmo::svm::oneclass::OneClassConfig::new(0.2, 0.5),
    );
    let oc_path = dir.path("oc.json");
    oc.save(&oc_path).unwrap();

    let blobs = pasmo::data::multiclass::blobs(150, 3, 5.0, 0.4, 22);
    let ovo = pasmo::svm::multiclass::train_ovo(&blobs, &pasmo::svm::Trainer::rbf(10.0, 0.3));
    let ovo_path = dir.path("ovo.json");
    ovo.save(&ovo_path).unwrap();

    let server = ServeChild::spawn(
        &format!(
            "svc={},oc={},ovo={}",
            svc_path.display(),
            oc_path.display(),
            ovo_path.display()
        ),
        &[],
    );
    let mut conn = server.connect();

    // Expectations come from reloading the exact files the server loaded.
    let svc = pasmo::svm::SvmModel::load(&svc_path).unwrap();
    let oc = pasmo::svm::oneclass::OneClassModel::load(&oc_path).unwrap();
    let ovo = pasmo::svm::multiclass::OvoModel::load(&ovo_path).unwrap();

    let x2 = train.row(0);
    let v = parse_reply(&conn.roundtrip(&score_line(Some("svc"), x2, 1)));
    assert_eq!(v.get("kind").and_then(Json::as_str), Some("classify"));
    let served = v.get("decision").and_then(Json::as_f64).unwrap();
    assert_eq!(served.to_bits(), svc.decision(x2).to_bits(), "svc decision bits");

    let v = parse_reply(&conn.roundtrip(&score_line(Some("oc"), x2, 2)));
    assert_eq!(v.get("kind").and_then(Json::as_str), Some("oneclass"));
    let served = v.get("decision").and_then(Json::as_f64).unwrap();
    assert_eq!(served.to_bits(), oc.decision(x2).to_bits(), "oneclass decision bits");

    let x_multi = blobs.row(3);
    let v = parse_reply(&conn.roundtrip(&score_line(Some("ovo"), x_multi, 3)));
    assert_eq!(v.get("kind").and_then(Json::as_str), Some("multiclass"));
    assert_eq!(
        v.get("prediction").and_then(Json::as_f64),
        Some(ovo.predict(x_multi) as f64)
    );

    // Error paths: each gets `ok:false` + a pointed message, and the
    // connection survives every one of them.
    let cases = [
        (score_line(None, x2, 4), "must name one"),
        (score_line(Some("nope"), x2, 5), "unknown model"),
        (score_line(Some("svc"), &x2[..1], 6), "expects 2"),
        ("this is not json".to_string(), "bad json"),
    ];
    for (line, needle) in &cases {
        let v = parse_reply(&conn.roundtrip(line));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false), "{line}");
        let err = v.get("error").and_then(Json::as_str).unwrap_or("");
        assert!(err.contains(needle), "{line:?} → {err:?} (wanted {needle:?})");
    }

    // `{"cmd":"models"}` lists all three.
    let v = parse_reply(&conn.roundtrip("{\"cmd\":\"models\"}"));
    let listed = v.get("models").unwrap();
    for name in ["svc", "oc", "ovo"] {
        assert!(listed.get(name).is_some(), "{name} missing from listing");
    }

    // Hot-swap: retrain under different hyperparameters, load over the
    // same name, and the served decision switches to the new model's
    // bits without dropping the connection.
    let svc2 = pasmo::svm::Trainer::rbf(10.0, 1.5).train(&train).model;
    let svc2_path = dir.path("svc2.json");
    svc2.save(&svc2_path).unwrap();
    let v = parse_reply(&conn.roundtrip(&format!(
        "{{\"cmd\":\"load\",\"name\":\"svc\",\"path\":{:?}}}",
        svc2_path.to_str().unwrap()
    )));
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "hot-swap failed");
    assert_eq!(v.get("loaded").and_then(Json::as_str), Some("svc"));
    let svc2 = pasmo::svm::SvmModel::load(&svc2_path).unwrap();
    let v = parse_reply(&conn.roundtrip(&score_line(Some("svc"), x2, 7)));
    let served = v.get("decision").and_then(Json::as_f64).unwrap();
    assert_eq!(served.to_bits(), svc2.decision(x2).to_bits(), "post-swap bits");
    assert_ne!(
        served.to_bits(),
        svc.decision(x2).to_bits(),
        "swap should change the decision function"
    );

    server.shutdown();
}

/// `pasmo serve` argument validation fails fast, before binding.
#[test]
fn serve_rejects_bad_model_specs() {
    let out = pasmo().args(["serve", "--addr", "127.0.0.1:0"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--model"));

    let dir = TempDir::new("serve-badspec");
    let model = dir.path("model.json");
    let out = pasmo()
        .args(["train", "--dataset", "banana", "--len", "150", "--out"])
        .arg(&model)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let spec = format!("a={},a={}", model.display(), model.display());
    let out = pasmo()
        .args(["serve", "--addr", "127.0.0.1:0", "--model", &spec])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("duplicate model name"));
}

/// `pasmo bench --serve` writes the BENCH_serve.json artifact with one
/// run per `--batches` config, each reporting queries/s and tail
/// latency.
#[test]
fn bench_serve_writes_saturation_json() {
    let dir = TempDir::new("bench-serve");
    let path = dir.path("BENCH_serve.json");
    let out = pasmo()
        .args([
            "bench", "--serve", "--len", "150", "--rate", "800", "--queries", "160",
            "--conns", "2", "--batches", "1,16", "--out",
        ])
        .arg(&path)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "bench --serve failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let doc =
        pasmo::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(doc.get("bench").unwrap().as_str(), Some("serve"));
    let runs = doc.get("runs").unwrap().as_arr().unwrap();
    assert_eq!(runs.len(), 2, "one run per --batches config");
    for (r, want_batch) in runs.iter().zip([1.0, 16.0]) {
        assert_eq!(r.get("max_batch").unwrap().as_f64(), Some(want_batch));
        assert!(r.get("queries_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("p99_us").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(r.get("errors").unwrap().as_f64(), Some(0.0));
        assert_eq!(r.get("ok").unwrap().as_f64(), Some(160.0));
    }
}

// ---------------------------------------------------------------------------
// Sparse substrate end to end: train → predict → serve over a CSR-backed
// LIBSVM file, bit-matched against the same file forced dense.
// ---------------------------------------------------------------------------

/// A 0.1%-density LIBSVM file (2 stored entries out of 2000 dims) runs
/// the whole CLI pipeline through the CSR backend — `train --storage
/// sparse`, `predict --storage sparse --mmap`, `serve` with sparse JSON
/// queries — and every decision is bit-identical to the same file
/// trained and scored with `--storage dense`.
#[test]
fn sparse_pipeline_matches_dense_pipeline_bit_for_bit() {
    use pasmo::util::json::Json;
    let dir = TempDir::new("sparse-e2e");

    let ds = pasmo::data::synth::sparse_blobs(300, 2000, 2, 77);
    assert!(ds.is_sparse());
    let data_path = dir.path("sparse.libsvm");
    pasmo::data::libsvm::write(&ds, &data_path).unwrap();

    // Train the same file through both backends.
    let mut models = Vec::new();
    for storage in ["sparse", "dense"] {
        let model = dir.path(&format!("model-{storage}.json"));
        let out = pasmo()
            .args(["train", "--libsvm"])
            .arg(&data_path)
            .args(["--storage", storage, "--out"])
            .arg(&model)
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "train --storage {storage}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        assert!(model.exists());
        models.push(model);
    }

    // Predict through each backend (the sparse leg additionally takes
    // the mapped reader); the full-precision decision files must match
    // byte for byte.
    let mut preds = Vec::new();
    for (i, (storage, extra)) in
        [("sparse", vec!["--mmap"]), ("dense", vec![])].into_iter().enumerate()
    {
        let p = dir.path(&format!("preds-{storage}.txt"));
        let out = pasmo()
            .args(["predict", "--model"])
            .arg(&models[i])
            .args(["--libsvm"])
            .arg(&data_path)
            .args(["--storage", storage])
            .args(&extra)
            .args(["--out"])
            .arg(&p)
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "predict --storage {storage}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        preds.push(std::fs::read_to_string(&p).unwrap());
    }
    assert!(!preds[0].is_empty());
    assert_eq!(preds[0], preds[1], "sparse and dense decision files diverge");
    let offline: Vec<f64> = preds[0]
        .lines()
        .map(|l| l.split_whitespace().nth(1).unwrap().parse().unwrap())
        .collect();
    assert_eq!(offline.len(), ds.len());

    // Serve the sparse-trained model and replay the first rows as sparse
    // JSON queries ({"x":{"<1-based index>":value}}): the socket answers
    // with the offline bits.
    let server = ServeChild::spawn(&format!("s={}", models[0].display()), &[]);
    let mut conn = server.connect();
    let n_q = 40usize;
    for i in 0..n_q {
        let mut line = String::from("{\"x\":{");
        let mut first = true;
        ds.row_ref(i).for_each_entry(|k, v| {
            if v != 0.0 {
                if !first {
                    line.push(',');
                }
                first = false;
                line.push_str(&format!("\"{}\":{v}", k + 1));
            }
        });
        line.push_str(&format!("}},\"id\":{i}}}"));
        conn.send(&line);
    }
    for (i, want) in offline.iter().take(n_q).enumerate() {
        let v = parse_reply(&conn.recv());
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "query {i}: {v:?}");
        assert_eq!(v.get("id").and_then(Json::as_f64), Some(i as f64), "reply order");
        let served = v.get("decision").and_then(Json::as_f64).unwrap();
        assert_eq!(
            served.to_bits(),
            want.to_bits(),
            "query {i}: served {served} != offline {want}"
        );
    }
    server.shutdown();
}

/// `pasmo bench --sparse` sweeps density 1.0 → 0.001 and enforces the
/// bytes-resident gate: at the low densities CSR must actually beat the
/// dense twin's footprint. The JSON document carries both columns.
#[test]
fn bench_sparse_sweeps_density_and_reports_resident_bytes() {
    use pasmo::util::json::Json;
    let dir = TempDir::new("bench-sparse");
    let path = dir.path("sparse.json");
    let out = pasmo()
        .args(["bench", "--sparse", "--len", "60", "--dim", "500", "--out"])
        .arg(&path)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "bench --sparse failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(doc.get("bench").unwrap().as_str(), Some("sparse"));
    let runs = doc.get("runs").unwrap().as_arr().unwrap();
    assert_eq!(runs.len(), 3, "one run per density");
    for r in runs {
        let rows = r.get("rows").unwrap().as_f64().unwrap();
        assert!(rows > 0.0);
        assert!(r.get("rows_per_s").unwrap().as_f64().unwrap() > 0.0);
        let resident = r.get("bytes_resident").unwrap().as_f64().unwrap();
        let dense = r.get("dense_bytes").unwrap().as_f64().unwrap();
        assert!(resident > 0.0 && dense > 0.0);
        if r.get("density").unwrap().as_str() == Some("0.001") {
            assert!(
                resident < dense,
                "0.001-density CSR resident {resident} !< dense {dense}"
            );
            // the lowest density runs at 10× the row count
            assert_eq!(rows, 600.0, "0.001 density runs at 10x --len");
        }
    }
}
