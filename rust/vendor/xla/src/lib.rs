//! Offline stub of the subset of the `xla` PJRT binding that
//! `pasmo::runtime` consumes.
//!
//! The build environment has no network access and no PJRT plugin, but the
//! `pjrt` cargo feature must still *compile* so the runtime layer cannot
//! silently rot. This crate mirrors the API shape of the real binding
//! (`PjRtClient::cpu()` → compile HLO → `execute_b` → literal readback)
//! and fails at the first runtime step — client creation — with a clear
//! error. Swapping the `vendor/xla` path dependency for a real binding
//! restores execution without touching `pasmo` itself.

use std::fmt;

/// Error type matching the binding's `Display`-able error.
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: the offline `xla` stub cannot execute; link a real PJRT binding \
             (replace the `vendor/xla` path dependency) to run artifacts"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. The stub has no PJRT plugin, so this is the
    /// single point of failure for every runtime path.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("create PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile computation"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("upload host buffer"))
    }
}

/// Device-resident buffer (stub: unconstructible through public API).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("read back literal"))
    }
}

/// Compiled executable (stub: unconstructible through public API).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute"))
    }
}

/// Host-side literal value.
pub struct Literal;

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("unwrap 1-tuple literal"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("literal to vec"))
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parsing HLO text requires the real binding's proto support.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("parse HLO text file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_with_actionable_message() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        let msg = err.to_string();
        assert!(msg.contains("stub"), "{msg}");
        assert!(msg.contains("PJRT"), "{msg}");
    }

    #[test]
    fn proto_parsing_fails_cleanly() {
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
