//! Regenerates paper Table 1: dataset statistics (SV / BSV) under the
//! paper's hyper-parameters.

mod common;

fn main() {
    common::banner("bench_table1", "paper Table 1 (datasets, C, γ, SV, BSV)");
    let opts = common::bench_options();
    let t0 = std::time::Instant::now();
    println!("{}", pasmo::coordinator::experiments::table1(&opts));
    println!("total: {:.2}s", t0.elapsed().as_secs_f64());
}
