//! Kernel-row throughput: native Rust computer vs the PJRT/AOT artifact
//! path, across dataset sizes and feature dims (DESIGN.md P1).
//!
//! Reports rows/s and effective GFLOP/s (2·ℓ·d flops per row for the dot
//! products, plus the exp). This is the L1/L3 boundary the perf pass
//! optimizes. The PJRT columns appear only when built with
//! `--features pjrt` *and* artifacts are present.

use std::sync::Arc;

use pasmo::data::dataset::Dataset;
use pasmo::kernel::matrix::RowComputer;
use pasmo::kernel::tile::simd::{self, SimdMode};
use pasmo::kernel::{KernelFunction, NativeRowComputer};
use pasmo::util::prng::Pcg;
use pasmo::util::timer::bench;

/// Re-select the tile the way process startup would (PASMO_SIMD or
/// auto), after a section that forced a mode.
fn restore_ambient_simd() {
    let ambient = std::env::var("PASMO_SIMD")
        .ok()
        .and_then(|v| SimdMode::parse(&v))
        .unwrap_or(SimdMode::Auto);
    let _ = simd::set_simd_mode(ambient);
}

fn random_ds(n: usize, d: usize, seed: u64) -> Arc<Dataset> {
    let mut rng = Pcg::new(seed);
    let mut ds = Dataset::with_dim(d);
    let mut row = vec![0f32; d];
    for _ in 0..n {
        row.iter_mut().for_each(|v| *v = rng.normal() as f32);
        ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
    }
    Arc::new(ds)
}

fn flops(n: usize, d: usize) -> f64 {
    (n * (2 * d + 4)) as f64 // per full row
}

fn report(r: &pasmo::util::timer::BenchResult, n: usize, d: usize) {
    println!(
        "{}   {:>8.1} rows/s  {:>7.2} GFLOP/s",
        r.line(),
        1.0 / r.mean_s,
        flops(n, d) / r.mean_s / 1e9
    );
}

/// One engine shared across all dataset sizes, so the per-artifact
/// executable memoization is exercised instead of recompiling per size.
#[cfg(feature = "pjrt")]
type Engine = Option<std::rc::Rc<pasmo::runtime::engine::PjrtEngine>>;
#[cfg(not(feature = "pjrt"))]
type Engine = ();

#[cfg(feature = "pjrt")]
fn open_engine() -> Engine {
    match pasmo::runtime::engine::PjrtEngine::open_default() {
        Ok(e) => Some(std::rc::Rc::new(e)),
        Err(_) => {
            println!("(PJRT artifacts missing — native only; run `make artifacts`)\n");
            None
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn open_engine() -> Engine {
    println!("(built without the `pjrt` feature — native only)\n");
}

#[cfg(feature = "pjrt")]
fn bench_pjrt(engine: &Engine, ds: &Arc<Dataset>, n: usize, d: usize, out: &mut [f32]) {
    use pasmo::runtime::gram::PjrtRowComputer;

    let Some(engine) = engine else {
        return; // banner already printed by open_engine
    };
    match PjrtRowComputer::new(engine.clone(), ds.clone(), 0.5) {
        Ok(pjrt) => {
            let mut i = 0usize;
            let r = bench(&format!("pjrt    l={n:<6} d={d:<4}"), 10, || {
                i = (i + 17) % n;
                pjrt.compute_row(i, out);
                out[0]
            });
            report(&r, n, d);
        }
        Err(e) => println!("pjrt    l={n:<6} d={d:<4}: unavailable ({e})"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_pjrt(_engine: &Engine, _ds: &Arc<Dataset>, _n: usize, _d: usize, _out: &mut [f32]) {}

fn main() {
    println!("==== bench_kernel_throughput ====");
    println!("gram-row evaluation: native Rust vs PJRT artifact (DESIGN.md P1)\n");
    let engine = open_engine();

    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    for &(n, d) in &[(1000usize, 2usize), (4096, 16), (4096, 64), (16384, 64), (8192, 200)] {
        let ds = random_ds(n, d, 42);
        let native = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.5 });
        let mut out = vec![0f32; n];
        let mut i = 0usize;
        let r = bench(&format!("native  l={n:<6} d={d:<4}"), 20, || {
            i = (i + 17) % n;
            native.compute_row(i, &mut out);
            out[0]
        });
        report(&r, n, d);

        // multi-threaded tiled rows (same bits, more cores)
        let mt = NativeRowComputer::with_threads(
            ds.clone(),
            KernelFunction::Rbf { gamma: 0.5 },
            threads,
        );
        let mut i = 0usize;
        let r = bench(&format!("nat-t{threads:<2} l={n:<6} d={d:<4}"), 20, || {
            i = (i + 17) % n;
            mt.compute_row(i, &mut out);
            out[0]
        });
        report(&r, n, d);

        // shrink-aware gathered rows at a quarter of the columns: kernel
        // work (and GFLOP/s denominator) scales with the active prefix
        let cols: Vec<usize> = (0..n / 4).map(|p| (p * 3) % n).collect();
        let mut short = vec![0f32; cols.len()];
        let mut i = 0usize;
        let r = bench(&format!("nat-¼   l={n:<6} d={d:<4}"), 20, || {
            i = (i + 17) % n;
            native.compute_cols(i, &cols, &mut short);
            short[0]
        });
        println!(
            "{}   {:>8.1} rows/s  {:>7.2} GFLOP/s (quarter rows)",
            r.line(),
            1.0 / r.mean_s,
            flops(n / 4, d) / r.mean_s / 1e9
        );

        bench_pjrt(&engine, &ds, n, d, &mut out);
        println!();
    }

    // Density sweep: the same gram-row kernel over the CSR backend at
    // decreasing stored density, against each dataset's dense twin. The
    // bytes-resident column is what the sparse substrate buys; rows/s
    // shows where the merge-style sparse dot crosses the dense SIMD loop.
    println!("---- density sweep (CSR vs dense twin, RBF γ=0.5) ----");
    let n = 4096usize;
    let d = 2000usize;
    for &(label, nnz) in &[("1.0  ", d), ("0.1  ", d / 10), ("0.001", d / 1000)] {
        let sparse = Arc::new(pasmo::data::synth::sparse_blobs(n, d, nnz, 42));
        let dense = Arc::new(sparse.to_dense());
        for (tag, ds) in [("csr  ", &sparse), ("dense", &dense)] {
            let native = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.5 });
            let mut out = vec![0f32; n];
            let mut i = 0usize;
            let r = bench(&format!("{tag} density={label} l={n:<6} d={d:<4}"), 10, || {
                i = (i + 17) % n;
                native.compute_row(i, &mut out);
                out[0]
            });
            println!(
                "{}   {:>8.1} rows/s  {:>12} bytes resident",
                r.line(),
                1.0 / r.mean_s,
                ds.resident_bytes()
            );
        }
        // scalar vs SIMD on the dense twin at this density (CSR rows
        // always take the merged-dot fallback, so only dense splits)
        if simd::simd_supported() {
            let native = NativeRowComputer::new(dense.clone(), KernelFunction::Rbf { gamma: 0.5 });
            let mut out = vec![0f32; n];
            for (mtag, mode) in [("dense·scalar", SimdMode::Off), ("dense·simd  ", SimdMode::Force)]
            {
                assert!(simd::set_simd_mode(mode));
                let mut i = 0usize;
                let r = bench(&format!("{mtag} density={label}"), 10, || {
                    i = (i + 17) % n;
                    native.compute_row(i, &mut out);
                    out[0]
                });
                println!("{}   {:>8.1} rows/s", r.line(), 1.0 / r.mean_s);
            }
            restore_ambient_simd();
        }
        println!();
    }

    // Scalar vs SIMD tile per kernel × dim. d = 2 and 3 are the
    // sub-4-entry remainder-only shapes (the SIMD tile requires d >= 4,
    // so they dispatch scalar under both modes and the speedup column
    // reads ~1x); bit-identity is asserted on a full row each time.
    println!("---- scalar vs SIMD tile (dense, bit-identical by construction) ----");
    if !simd::simd_supported() {
        println!("(no AVX2 on this host — SIMD rows skipped, the scalar tile is the floor)");
        return;
    }
    let kernels: [(&str, KernelFunction); 4] = [
        ("rbf    ", KernelFunction::Rbf { gamma: 0.5 }),
        ("linear ", KernelFunction::Linear),
        ("poly   ", KernelFunction::Poly { gamma: 0.5, coef0: 1.0, degree: 3 }),
        ("sigmoid", KernelFunction::Sigmoid { gamma: 0.5, coef0: 0.0 }),
    ];
    for &(kname, kernel) in &kernels {
        for &d in &[2usize, 3, 16, 64, 200] {
            let n = 4096usize;
            let ds = random_ds(n, d, 42);
            let native = NativeRowComputer::new(ds.clone(), kernel);
            let mut out_off = vec![0f32; n];
            let mut out_on = vec![0f32; n];

            assert!(simd::set_simd_mode(SimdMode::Off));
            let mut i = 0usize;
            let r_off = bench(&format!("{kname} scalar d={d:<4}"), 10, || {
                i = (i + 17) % n;
                native.compute_row(i, &mut out_off);
                out_off[0]
            });
            assert!(simd::set_simd_mode(SimdMode::Force));
            let mut i = 0usize;
            let r_on = bench(&format!("{kname} simd   d={d:<4}"), 10, || {
                i = (i + 17) % n;
                native.compute_row(i, &mut out_on);
                out_on[0]
            });

            // one full row under each mode: the tiles must agree bitwise
            assert!(simd::set_simd_mode(SimdMode::Off));
            native.compute_row(0, &mut out_off);
            assert!(simd::set_simd_mode(SimdMode::Force));
            native.compute_row(0, &mut out_on);
            for (a, b) in out_off.iter().zip(&out_on) {
                assert_eq!(a.to_bits(), b.to_bits(), "{kname} d={d}: SIMD row diverged");
            }

            println!("{}   {:>8.1} rows/s", r_off.line(), 1.0 / r_off.mean_s);
            println!(
                "{}   {:>8.1} rows/s   {:>5.2}x vs scalar (bits identical)",
                r_on.line(),
                1.0 / r_on.mean_s,
                r_off.mean_s / r_on.mean_s
            );
        }
        println!();
    }
    restore_ambient_simd();
}
