//! Kernel-row throughput: native Rust computer vs the PJRT/AOT artifact
//! path, across dataset sizes and feature dims (DESIGN.md P1).
//!
//! Reports rows/s and effective GFLOP/s (2·ℓ·d flops per row for the dot
//! products, plus the exp). This is the L1/L3 boundary the perf pass
//! optimizes.

use std::rc::Rc;
use std::sync::Arc;

use pasmo::data::dataset::Dataset;
use pasmo::kernel::matrix::RowComputer;
use pasmo::kernel::{KernelFunction, NativeRowComputer};
use pasmo::runtime::engine::PjrtEngine;
use pasmo::runtime::gram::PjrtRowComputer;
use pasmo::util::prng::Pcg;
use pasmo::util::timer::bench;

fn random_ds(n: usize, d: usize, seed: u64) -> Arc<Dataset> {
    let mut rng = Pcg::new(seed);
    let mut ds = Dataset::with_dim(d);
    let mut row = vec![0f32; d];
    for _ in 0..n {
        row.iter_mut().for_each(|v| *v = rng.normal() as f32);
        ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
    }
    Arc::new(ds)
}

fn flops(n: usize, d: usize) -> f64 {
    (n * (2 * d + 4)) as f64 // per full row
}

fn main() {
    println!("==== bench_kernel_throughput ====");
    println!("gram-row evaluation: native Rust vs PJRT artifact (DESIGN.md P1)\n");
    let engine = PjrtEngine::open_default().ok().map(Rc::new);
    if engine.is_none() {
        println!("(PJRT artifacts missing — native only; run `make artifacts`)\n");
    }

    for &(n, d) in &[(1000usize, 2usize), (4096, 16), (4096, 64), (16384, 64), (8192, 200)] {
        let ds = random_ds(n, d, 42);
        let native = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.5 });
        let mut out = vec![0f32; n];
        let mut i = 0usize;
        let r = bench(&format!("native  l={n:<6} d={d:<4}"), 20, || {
            i = (i + 17) % n;
            native.compute_row(i, &mut out);
            out[0]
        });
        println!(
            "{}   {:>8.1} rows/s  {:>7.2} GFLOP/s",
            r.line(),
            1.0 / r.mean_s,
            flops(n, d) / r.mean_s / 1e9
        );

        if let Some(engine) = &engine {
            match PjrtRowComputer::new(engine.clone(), ds.clone(), 0.5) {
                Ok(pjrt) => {
                    let mut i = 0usize;
                    let r = bench(&format!("pjrt    l={n:<6} d={d:<4}"), 10, || {
                        i = (i + 17) % n;
                        pjrt.compute_row(i, &mut out);
                        out[0]
                    });
                    println!(
                        "{}   {:>8.1} rows/s  {:>7.2} GFLOP/s",
                        r.line(),
                        1.0 / r.mean_s,
                        flops(n, d) / r.mean_s / 1e9
                    );
                }
                Err(e) => println!("pjrt    l={n:<6} d={d:<4}: unavailable ({e})"),
            }
        }
        println!();
    }
}
