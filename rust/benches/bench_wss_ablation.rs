//! Regenerates the §7.2 ablation: modified working-set selection without
//! planning vs plain SMO vs full PA-SMO — shows the speed-up comes from
//! planning, not from the WSS change.

mod common;

fn main() {
    common::banner("bench_wss_ablation", "paper §7.2 (WSS-only vs planning)");
    let opts = common::bench_options();
    let t0 = std::time::Instant::now();
    println!("{}", pasmo::coordinator::experiments::wss_ablation(&opts));
    println!("total: {:.2}s", t0.elapsed().as_secs_f64());
}
