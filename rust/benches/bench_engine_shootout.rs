//! Regenerates the engine shootout — SMO vs PA-SMO vs Conjugate SMO on
//! paired permutations: iterations (with Wilcoxon '>' markers against
//! the SMO baseline), runtime, and the cross-engine objective-parity
//! column.

mod common;

fn main() {
    common::banner(
        "bench_engine_shootout",
        "engine shootout (SMO vs PA-SMO vs CSMO iterations + time, Wilcoxon '>')",
    );
    let opts = common::bench_options();
    let t0 = std::time::Instant::now();
    println!("{}", pasmo::coordinator::experiments::engine_shootout(&opts));
    println!("total: {:.2}s", t0.elapsed().as_secs_f64());
}
