//! Kernel-cache behaviour (DESIGN.md P2): hit rate and end-to-end solver
//! time as a function of the cache budget — the paper's §2 claim that
//! caching + shrinking "result in an enormous speed up".

use std::sync::Arc;

use pasmo::data::synth::chessboard;
use pasmo::kernel::matrix::Gram;
use pasmo::kernel::{KernelFunction, NativeRowComputer};
use pasmo::solver::pasmo::PasmoSolver;
use pasmo::solver::smo::SolverConfig;
use pasmo::solver::{Engine, QpProblem};

fn main() {
    println!("==== bench_cache ====");
    println!("PA-SMO on chess-board-600 (C=1e6) under varying cache budgets\n");
    let ds = Arc::new(chessboard(600, 4, 1));
    println!(
        "{:>12} {:>10} {:>10} {:>10} {:>10} {:>8}",
        "cache", "time", "iters", "hits", "misses", "hit-rate"
    );
    for &budget in &[
        2 * 600 * 4,          // pathological: the working pair only
        32 * 600 * 4,         // 32 rows
        128 * 600 * 4,        // 128 rows
        600 * 600 * 4,        // full matrix
        100 * 1024 * 1024usize, // LIBSVM default
    ] {
        let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma: 0.5 });
        let mut gram = Gram::new(Box::new(nc), budget);
        let cfg = SolverConfig { cache_bytes: budget, ..Default::default() };
        let res =
            PasmoSolver::new(cfg).solve(&QpProblem::classification(ds.labels(), 1e6), &mut gram);
        let s = res.cache_stats;
        println!(
            "{:>12} {:>9.3}s {:>10} {:>10} {:>10} {:>7.1}%",
            format!("{}KiB", budget / 1024),
            res.wall_time_s,
            res.iterations,
            s.hits,
            s.misses,
            100.0 * s.hit_rate()
        );
        assert!(res.converged);
    }
    println!("\nexpectation: hit-rate ↑ and time ↓ monotonically with budget");
}
