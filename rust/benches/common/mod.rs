//! Shared options for the bench targets.
//!
//! Every bench regenerates one paper table/figure at a CI-friendly scale
//! by default. Environment knobs:
//! * `PASMO_BENCH_FULL=1` — paper-scale suite (22 datasets, paper ℓ).
//! * `PASMO_BENCH_PERMS=N` — permutations per dataset (default 5).
//! * `PASMO_BENCH_MAXLEN=N` — ℓ cap in fast mode (default 600).

use pasmo::coordinator::experiments::ExpOptions;

pub fn bench_options() -> ExpOptions {
    let envn = |k: &str, d: usize| -> usize {
        std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
    };
    let mut o = ExpOptions::default();
    o.full = std::env::var("PASMO_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    o.perms = envn("PASMO_BENCH_PERMS", 5);
    o.max_len = envn("PASMO_BENCH_MAXLEN", 600);
    o.scale = 0.2;
    o
}

/// Print the standard bench banner.
pub fn banner(name: &str, what: &str) {
    println!("==== {name} ====");
    println!("regenerates: {what}");
    let o = bench_options();
    println!(
        "mode: {} | perms={} max_len={} scale={}\n",
        if o.full { "FULL (paper scale)" } else { "fast (set PASMO_BENCH_FULL=1 for paper scale)" },
        o.perms,
        o.max_len,
        o.scale
    );
}
