//! Regenerates the §7.3 "heretical" experiment: fixed 1.1× over-relaxed
//! Newton steps vs SMO vs PA-SMO (including the chess-board where the
//! cheap trick falls behind). Also prints the Figure-2 gain parabola.

mod common;

fn main() {
    common::banner(
        "bench_heuristic_step",
        "paper §7.3 (1.1× over-relaxation) + Figure 2 (gain parabola)",
    );
    let opts = common::bench_options();
    let t0 = std::time::Instant::now();
    println!("{}", pasmo::coordinator::experiments::fig2());
    println!("{}", pasmo::coordinator::experiments::heuristic_step(&opts));
    println!("total: {:.2}s", t0.elapsed().as_secs_f64());
}
