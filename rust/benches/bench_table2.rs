//! Regenerates paper Table 2 — the headline comparison: mean runtime and
//! iteration count of SMO vs PA-SMO over paired permutations with
//! Wilcoxon significance markers.

mod common;

fn main() {
    common::banner(
        "bench_table2",
        "paper Table 2 (SMO vs PA-SMO time + iterations, Wilcoxon '>')",
    );
    let opts = common::bench_options();
    let t0 = std::time::Instant::now();
    println!("{}", pasmo::coordinator::experiments::table2(&opts));
    println!("total: {:.2}s", t0.elapsed().as_secs_f64());
}
