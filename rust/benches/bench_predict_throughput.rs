//! Batch-prediction throughput: the seed's scalar per-SV decision loop
//! vs the tiled batch `Scorer`, the threaded scorer, and the linear
//! primal collapse, across (n_sv, d, queries) shapes (DESIGN.md P3).
//!
//! Columns: mean time per full scoring pass, queries/s, and kernel
//! entries evaluated per pass (q·n_sv for the expansion, 0 for the
//! collapsed linear path). The scorer rows must beat the scalar row —
//! that is the inference-side speedup this instrument exists to track.
//! `PASMO_BENCH_FULL=1` enlarges the shapes.

use pasmo::data::dataset::Dataset;
use pasmo::kernel::tile::simd::{self, SimdMode};
use pasmo::kernel::KernelFunction;
use pasmo::svm::scorer::Scorer;
use pasmo::util::prng::Pcg;
use pasmo::util::timer::bench;

/// Re-select the tile the way process startup would (PASMO_SIMD or
/// auto), after a section that forced a mode.
fn restore_ambient_simd() {
    let ambient = std::env::var("PASMO_SIMD")
        .ok()
        .and_then(|v| SimdMode::parse(&v))
        .unwrap_or(SimdMode::Auto);
    let _ = simd::set_simd_mode(ambient);
}

fn random_ds(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Pcg::new(seed);
    let mut ds = Dataset::with_dim(d);
    let mut row = vec![0f32; d];
    for _ in 0..n {
        row.iter_mut().for_each(|v| *v = rng.normal() as f32);
        ds.push(&row, if rng.bernoulli(0.5) { 1 } else { -1 });
    }
    ds
}

fn random_coef(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

/// The pre-scorer baseline: per example, per SV, `KernelFunction::eval`.
fn scalar_pass(
    kernel: KernelFunction,
    sv: &Dataset,
    coef: &[f64],
    bias: f64,
    queries: &Dataset,
) -> f64 {
    let mut acc = 0.0;
    for i in 0..queries.len() {
        let x = queries.row(i);
        let mut f = bias;
        for s in 0..sv.len() {
            f += coef[s] * kernel.eval(sv.row(s), x);
        }
        acc += f;
    }
    acc
}

fn report(r: &pasmo::util::timer::BenchResult, q: usize, entries: u64) {
    println!(
        "{}   {:>10.1} queries/s  {:>12} K-entries/pass",
        r.line(),
        q as f64 / r.mean_s,
        entries
    );
}

fn main() {
    println!("==== bench_predict_throughput ====");
    println!("batch decision-function evaluation: scalar loop vs tiled/threaded scorer (DESIGN.md P3)\n");

    let full = std::env::var("PASMO_BENCH_FULL").is_ok();
    let shapes: &[(usize, usize, usize)] = if full {
        &[(1000, 16, 4096), (4000, 64, 4096), (8000, 200, 2048)]
    } else {
        &[(300, 8, 512), (1000, 32, 1024), (2000, 64, 512)]
    };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let samples = if full { 20 } else { 10 };

    for &(n_sv, d, q) in shapes {
        let sv = random_ds(n_sv, d, 7);
        let coef = random_coef(n_sv, 8);
        let queries = random_ds(q, d, 9);
        let bias = 0.125;
        let entries = (n_sv * q) as u64;

        let kernel = KernelFunction::Rbf { gamma: 0.5 };
        let r = bench(&format!("scalar  sv={n_sv:<5} d={d:<4} q={q:<5}"), samples, || {
            scalar_pass(kernel, &sv, &coef, bias, &queries)
        });
        report(&r, q, entries);

        let tiled = Scorer::new(kernel, &sv, &coef, bias);
        let r = bench(&format!("tiled   sv={n_sv:<5} d={d:<4} q={q:<5}"), samples, || {
            tiled.decision_values(&queries).iter().sum::<f64>()
        });
        report(&r, q, entries);

        // explicit scalar-vs-SIMD split of the same tiled pass (the
        // rows above/below run whatever the ambient selection picked)
        if simd::simd_supported() {
            let mut rows = Vec::new();
            for (mtag, mode) in [("simd-off", SimdMode::Off), ("simd-on ", SimdMode::Force)] {
                assert!(simd::set_simd_mode(mode));
                let r = bench(&format!("{mtag}sv={n_sv:<5} d={d:<4} q={q:<5}"), samples, || {
                    tiled.decision_values(&queries).iter().sum::<f64>()
                });
                report(&r, q, entries);
                rows.push(tiled.decision_values(&queries));
            }
            // the two passes must agree to the bit
            for (a, b) in rows[0].iter().zip(&rows[1]) {
                assert_eq!(a.to_bits(), b.to_bits(), "SIMD scoring pass diverged");
            }
            restore_ambient_simd();
        }

        // opt-in packed-f32 SV storage (dense×dense fast path; the gate
        // a server would apply is reported instead of asserted here)
        let f32_fast = Scorer::new(kernel, &sv, &coef, bias).with_f32_sv(true);
        let delta = f32_fast.f32_sv_max_delta();
        let r = bench(&format!("f32-sv  sv={n_sv:<5} d={d:<4} q={q:<5}"), samples, || {
            f32_fast.decision_values(&queries).iter().sum::<f64>()
        });
        println!(
            "{}   {:>10.1} queries/s  {:>12} K-entries/pass  (gate delta {delta:.2e})",
            r.line(),
            q as f64 / r.mean_s,
            entries
        );

        let threaded = Scorer::new(kernel, &sv, &coef, bias).with_threads(threads);
        let r = bench(
            &format!("tile-t{threads:<2}sv={n_sv:<5} d={d:<4} q={q:<5}"),
            samples,
            || threaded.decision_values(&queries).iter().sum::<f64>(),
        );
        report(&r, q, entries);

        let lin = KernelFunction::Linear;
        let expansion = Scorer::new(lin, &sv, &coef, bias).collapse_linear(false);
        let r = bench(&format!("lin-exp sv={n_sv:<5} d={d:<4} q={q:<5}"), samples, || {
            expansion.decision_values(&queries).iter().sum::<f64>()
        });
        report(&r, q, entries);

        let collapsed = Scorer::new(lin, &sv, &coef, bias);
        assert!(collapsed.is_collapsed());
        let r = bench(&format!("lin-col sv={n_sv:<5} d={d:<4} q={q:<5}"), samples, || {
            collapsed.decision_values(&queries).iter().sum::<f64>()
        });
        report(&r, q, 0);

        println!();
    }
}
