//! L3 hot-path microbenchmark (perf-pass instrument): per-iteration cost
//! of SMO vs PA-SMO, and its breakdown sensitivity to ℓ and shrinking.
//!
//! The solver's per-iteration work is O(active): one WSS scan, one
//! stopping scan, one gradient update over two rows. This bench reports
//! iterations/second so perf regressions in the loop show up directly.

use std::sync::Arc;

use pasmo::data::synth::{chessboard, surrogate, SurrogateSpec};
use pasmo::kernel::matrix::Gram;
use pasmo::kernel::{KernelFunction, NativeRowComputer};
use pasmo::solver::{Engine, EngineConfig, QpProblem, SolverChoice, SolverConfig};

fn run(name: &str, ds: &Arc<pasmo::data::Dataset>, c: f64, gamma: f64, pa: bool, shrink: bool) {
    let nc = NativeRowComputer::new(ds.clone(), KernelFunction::Rbf { gamma });
    // Cache sized at a quarter of the matrix so the kernel/cache layer is
    // exercised (the shrink-aware rows show up as fewer kernel entries).
    let cache_bytes = (ds.len() / 4).max(8) * ds.len() * 4;
    let mut gram = Gram::new(Box::new(nc), cache_bytes);
    let cfg = SolverConfig { shrinking: shrink, cache_bytes, ..Default::default() };
    let choice = if pa { SolverChoice::Pasmo } else { SolverChoice::Smo };
    let engine = EngineConfig::new(choice, cfg).build();
    let problem = QpProblem::classification(ds.labels(), c);
    let t0 = std::time::Instant::now();
    let res = engine.solve(&problem, &mut gram);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{name:<44} {:>8} iters  {:>8.3}s  {:>10.0} iters/s  {:>12} K-entries  {:>5.1}% hit  (planning {})",
        res.iterations,
        dt,
        res.iterations as f64 / dt,
        res.kernel_entries,
        100.0 * res.cache_stats.hit_rate(),
        res.telemetry.planning_steps
    );
}

fn main() {
    println!("==== bench_solver_hotpath ====");
    println!("per-iteration solver cost (native kernel path)\n");
    // ℓ=3000 takes minutes and is noise-prone on shared machines; opt in.
    let sizes: &[usize] = if std::env::var("PASMO_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
    {
        &[500, 1500, 3000]
    } else {
        &[500, 1500]
    };
    for &n in sizes {
        let cb = Arc::new(chessboard(n, 4, 1));
        run(&format!("SMO     chess-board ℓ={n} shrink=on"), &cb, 1e6, 0.5, false, true);
        run(&format!("SMO     chess-board ℓ={n} shrink=off"), &cb, 1e6, 0.5, false, false);
        run(&format!("PA-SMO  chess-board ℓ={n} shrink=on"), &cb, 1e6, 0.5, true, true);
        run(&format!("PA-SMO  chess-board ℓ={n} shrink=off"), &cb, 1e6, 0.5, true, false);
    }
    // a dense-SV problem (most variables active: worst case for the scans)
    let spec = SurrogateSpec { dim: 10, label_noise: 0.25, separation: 1.0, ..Default::default() };
    let hard = Arc::new(surrogate(1500, &spec, 3));
    run("SMO     noisy-surrogate ℓ=1500", &hard, 1.0, 0.05, false, true);
    run("PA-SMO  noisy-surrogate ℓ=1500", &hard, 1.0, 0.05, true, true);
}
