//! Regenerates paper Figure 3: histograms of the planning-step size
//! μ/μ*−1 in the paper's sign(t)·(10^{t²/2}−1) parameterization.

mod common;

fn main() {
    common::banner("bench_fig3_histograms", "paper Figure 3 (μ/μ*−1 histograms)");
    let mut opts = common::bench_options();
    // Figure 3 is about step-size telemetry, not timing: a few
    // oscillation-prone datasets carry the signal.
    if opts.datasets.is_empty() && !opts.full {
        opts.datasets = vec![
            "chess-board-1000".into(),
            "banana".into(),
            "titanic".into(),
            "ringnorm".into(),
        ];
    }
    let t0 = std::time::Instant::now();
    println!("{}", pasmo::coordinator::experiments::fig3(&opts));
    println!("total: {:.2}s", t0.elapsed().as_secs_f64());
}
