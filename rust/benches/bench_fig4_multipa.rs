//! Regenerates paper Figure 4: multiple planning-ahead with the
//! N ∈ {1,2,3,5,10,20} most recent working sets, runtime normalized to
//! N = 1.

mod common;

fn main() {
    common::banner("bench_fig4_multipa", "paper Figure 4 (multi-PA N sweep)");
    let mut opts = common::bench_options();
    if opts.datasets.is_empty() && !opts.full {
        // 6 solver variants × perms: keep the fast set focused on
        // datasets with runtimes above measurement noise (paper's filter).
        opts.datasets = vec![
            "chess-board-1000".into(),
            "banana".into(),
            "waveform".into(),
            "twonorm".into(),
        ];
    }
    let t0 = std::time::Instant::now();
    println!("{}", pasmo::coordinator::experiments::fig4(&opts));
    println!("total: {:.2}s", t0.elapsed().as_secs_f64());
}
