//! Serving-tier saturation: open-loop load against a real `pasmo serve`
//! socket across (max-batch × arrival-rate) configs.
//!
//! For each config the bench binds an in-process [`Server`] on an
//! ephemeral port, drives it with [`drive_open_loop`] (send times
//! scheduled up front — queueing shows up in the latency numbers rather
//! than being absorbed by a closed loop), and reports achieved
//! queries/s, p50/p99 latency, and the realized mean micro-batch size
//! from the server's own stats. The point being demonstrated: with the
//! same model and thread budget, admission micro-batching (max-batch >
//! 1) sustains rates that drown a batch-size-1 server, because each
//! drained batch amortizes one tiled SV×query pass over many queries.

use std::sync::Arc;

use pasmo::data::synth::chessboard;
use pasmo::server::{drive_open_loop, request_once, LoadConfig, ServeConfig, Server};
use pasmo::svm::schema::AnyModel;
use pasmo::svm::Trainer;
use pasmo::util::json::Json;

fn mean_batch_from_stats(addr: std::net::SocketAddr) -> f64 {
    request_once(addr, "{\"cmd\":\"stats\"}")
        .ok()
        .and_then(|stats| Json::parse(&stats).ok())
        .and_then(|v| v.get("models")?.get("bench")?.get("mean_batch")?.as_f64())
        .unwrap_or(0.0)
}

fn main() {
    println!("==== bench_serve ====");
    println!("open-loop saturation of the micro-batching serve tier\n");

    let len = 400;
    let train_set = Arc::new(chessboard(len, 4, 1));
    let queries = chessboard(256, 4, 2);
    let model = Trainer::rbf(1e3, 0.5).train(&train_set).model;
    println!("model: chess-board ℓ={len}, {} SVs, dim 2", model.n_sv());
    println!(
        "{:>9} {:>8} {:>8} {:>10} {:>10} {:>10} {:>11} {:>7}",
        "max-batch", "threads", "rate/s", "qps", "p50-us", "p99-us", "mean-batch", "errors"
    );

    for &(max_batch, threads) in &[(1usize, 1usize), (8, 1), (64, 1), (64, 2)] {
        for &rate in &[1000.0, 4000.0] {
            let config = ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                max_batch,
                max_wait_us: 200,
                threads,
                ..ServeConfig::default()
            };
            let server = match Server::bind(
                config,
                vec![("bench".to_string(), AnyModel::Svc(model.clone()))],
            ) {
                Ok(s) => s,
                Err(e) => {
                    println!("bind failed: {e:#}");
                    return;
                }
            };
            let addr = server.local_addr();
            let handle = std::thread::spawn(move || server.run());
            let cfg = LoadConfig { rate, queries: 2000, conns: 4 };
            let report = match drive_open_loop(
                addr,
                Some("bench"),
                queries.dim(),
                queries.features(),
                &cfg,
            ) {
                Ok(r) => r,
                Err(e) => {
                    println!("drive failed: {e:#}");
                    return;
                }
            };
            let mean_batch = mean_batch_from_stats(addr);
            let _ = request_once(addr, "{\"cmd\":\"shutdown\"}");
            let _ = handle.join();
            println!(
                "{:>9} {:>8} {:>8.0} {:>10.1} {:>10.0} {:>10.0} {:>11.2} {:>7}",
                max_batch,
                threads,
                rate,
                report.qps,
                report.p50_us,
                report.p99_us,
                mean_batch,
                report.errors
            );
        }
    }
    println!(
        "\nreading the table: at rates the batch-size-1 config cannot sustain\n\
         (qps < rate, p99 exploding), micro-batching configs hold qps ≈ rate\n\
         with bounded tails — the admission window amortizes one tiled pass\n\
         over mean-batch queries. `pasmo bench --serve` writes the same\n\
         sweep as BENCH_serve.json."
    );
}
