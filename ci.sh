#!/usr/bin/env bash
# CI gate for the pasmo workspace. Mirrors the tier-1 verify
# (`cargo build --release && cargo test -q`) and adds the guards that
# keep the offline build honest:
#   1. cargo fmt --check        (skipped when rustfmt is not installed)
#   2. cargo build --release    (tier-1, default features = native path)
#   3. cargo test -q            (tier-1)
#   4. cargo build --no-default-features
#                               (the native path must never grow a hard
#                                external dependency)
#   4b. cargo build --benches   (bench targets are not covered by build/test)
#   4c. cargo build --examples  (the 5 root-level examples are real
#                                [[example]] targets and must keep building)
#   4d. run the quickstart example at tiny scale (end-to-end smoke)
#   4e. pasmo bench at tiny scale → BENCH_solver.json (perf trajectory)
#   4e2. pasmo bench --predict at tiny scale → BENCH_predict.json
#                               (inference-side trajectory: scalar vs
#                                tiled vs threaded vs linear-collapse)
#   4f. docs gate: RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
#                               (zero rustdoc warnings — missing docs on
#                                any public item or a broken doc link
#                                fails here) + cargo test --doc
#   4g. pasmo experiment engine_shootout at tiny scale (the three-way
#                                SMO / PA-SMO / CSMO comparison stays
#                                runnable end to end)
#   4h. pasmo audit             (the repo's own source-tree lint: panics
#                                in library paths, undocumented unsafe,
#                                float ==, stray threads/prints, HashMap
#                                iteration — hard gate, audit.allow is
#                                the only escape hatch)
#   4i. cargo test -q --features debug-invariants
#                               (the whole suite again with the solver/
#                                cache invariant checkers compiled in)
#   4j. cargo clippy -D warnings (skipped when clippy is not installed)
#   4k. cargo +nightly miri test on the unsafe-heavy kernel modules
#                               (skipped when miri is not installed)
#   5. cargo build --features pjrt
#                               (the gated runtime module must keep
#                                compiling against the vendor/xla stub)
#   6. cargo test -q --features pjrt
#                               (runtime unit tests + the pjrt smoke test)
set -euo pipefail
cd "$(dirname "$0")/rust"

step() { printf '\n==== %s ====\n' "$*"; }

if cargo fmt --version >/dev/null 2>&1; then
    step "cargo fmt --check"
    cargo fmt --check
else
    step "cargo fmt --check (SKIPPED: rustfmt not installed)"
fi

step "cargo build --release"
cargo build --release

step "cargo test -q"
cargo test -q

step "cargo build --no-default-features"
cargo build --no-default-features

step "cargo build --benches"
cargo build --benches

step "cargo build --release --examples"
cargo build --release --examples

step "cargo run --release --example quickstart -- --len 200"
cargo run --release --example quickstart -- --len 200

# Perf baseline artifact: tiny-scale solver bench (wall time, iterations,
# kernel-entry counts, cache hit rates; shrink on vs off) written to the
# repo root so successive PRs have a trajectory to compare against.
step "pasmo bench --len 300 (writes ../BENCH_solver.json)"
cargo run --release -- bench --len 300 --cache-rows 32 --shrink-interval 50 --out ../BENCH_solver.json

# Inference baseline artifact: tiny-scale batch-scoring bench (queries/s
# and kernel entries for scalar vs tiled vs threaded vs linear-collapse).
step "pasmo bench --predict --len 300 (writes ../BENCH_predict.json)"
cargo run --release -- bench --predict --len 300 --out ../BENCH_predict.json

# Docs gate: the public surface is fully documented (#![warn(missing_docs)]
# promoted to an error here) and every doctest runs green.
step "cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

step "cargo test --doc"
cargo test -q --doc

# The three-way engine comparison stays runnable end to end.
step "pasmo experiment engine_shootout (tiny scale)"
cargo run --release -- experiment engine_shootout --datasets thyroid --perms 3 --max-len 150

# Source-tree lint: the binary audits its own src/ against audit.allow.
# Any unlisted panic path, undocumented unsafe, float ==, stray thread,
# print, or HashMap iteration — or a stale allowlist entry — fails CI.
step "pasmo audit"
cargo run --release --quiet -- audit

# Run the whole suite again with the invariant checkers compiled in:
# every solve in every test now validates Σα preservation, box bounds,
# perm/pos bijections, cache byte accounting and gradient parity at the
# shrink/unshrink seams.
step "cargo test -q --features debug-invariants"
cargo test -q --features debug-invariants

# Static analysis and UB detection are best-effort: the offline image may
# not ship clippy or miri, and the gate must not rot when they're absent.
if cargo clippy --version >/dev/null 2>&1; then
    step "cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    step "cargo clippy (SKIPPED: clippy not installed)"
fi

if cargo +nightly miri --version >/dev/null 2>&1; then
    # Scope miri to the unsafe-heavy kernel layer: full-suite miri is
    # orders of magnitude too slow for a CI gate.
    step "cargo +nightly miri test kernel::"
    cargo +nightly miri test kernel::
else
    step "cargo miri (SKIPPED: miri not installed)"
fi

step "cargo build --benches --features pjrt"
cargo build --benches --features pjrt

step "cargo build --examples --features pjrt"
cargo build --examples --features pjrt

step "cargo build --features pjrt"
cargo build --features pjrt

step "cargo test -q --features pjrt"
cargo test -q --features pjrt

step "OK"
