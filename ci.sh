#!/usr/bin/env bash
# CI gate for the pasmo workspace. Mirrors the tier-1 verify
# (`cargo build --release && cargo test -q`) and adds the guards that
# keep the offline build honest:
#   1. cargo fmt --check        (skipped when rustfmt is not installed)
#   2. cargo build --release    (tier-1, default features = native path)
#   3. cargo test -q            (tier-1)
#   3a. cargo test -q twice more under PASMO_SIMD=off and (AVX2 hosts)
#                               PASMO_SIMD=force: the scalar and SIMD
#                               kernel tiles are bit-identical by
#                               construction, so the whole suite must
#                               pass under either selection
#   4. cargo build --no-default-features
#                               (the native path must never grow a hard
#                                external dependency)
#   4b. cargo build --benches   (bench targets are not covered by build/test)
#   4c. cargo build --examples  (the 5 root-level examples are real
#                                [[example]] targets and must keep building)
#   4d. run the quickstart example at tiny scale (end-to-end smoke)
#   4e. pasmo bench at tiny scale → BENCH_solver.json (perf trajectory)
#   4e2. pasmo bench --predict at tiny scale → BENCH_predict.json
#                               (inference-side trajectory: scalar vs
#                                tiled vs threaded vs linear-collapse)
#   4e2a. pasmo bench --check-baseline against the committed
#                               ../BENCH_baseline.json (the persistent
#                               perf gate: regressions beyond noise
#                               tolerance exit nonzero; an empty
#                               committed metric map bootstraps)
#   4e2b. pasmo bench --sparse at tiny scale → BENCH_sparse.json
#                               (density sweep 1.0/0.1/0.001; the binary
#                                itself fails the run if CSR resident
#                                bytes don't beat the dense twin at low
#                                density) + a sparse train → predict
#                                round trip over a CSR-backed LIBSVM file
#   4e3. pasmo serve smoke: train a model, serve it on an ephemeral
#                                port, score one query + stats over
#                                /dev/tcp, then a clean shutdown
#   4e3b. pasmo serve overload smoke: a one-slot admission queue floods
#                                with a pipelined burst; the overflow is
#                                shed with explicit replies and the
#                                server still drains + exits 0
#   4e4. pasmo bench --serve at tiny scale → BENCH_serve.json
#                               (serving-tier saturation trajectory:
#                                queries/s + p50/p99 + shed/expired per
#                                max-batch)
#   4e5. chaos gate: cargo test -q --features fault-injection --test chaos
#                               (overload shedding, injected scoring
#                                panics → quarantine, injected write
#                                faults, checkpoint kill/resume, hot-swap
#                                under load)
#   4f. docs gate: RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
#                               (zero rustdoc warnings — missing docs on
#                                any public item or a broken doc link
#                                fails here) + cargo test --doc
#   4g. pasmo experiment engine_shootout at tiny scale (the three-way
#                                SMO / PA-SMO / CSMO comparison stays
#                                runnable end to end)
#   4h. pasmo audit             (the repo's own source-tree lint: panics
#                                in library paths, undocumented unsafe,
#                                float ==, stray threads/prints, HashMap
#                                iteration — hard gate, audit.allow is
#                                the only escape hatch)
#   4i. cargo test -q --features debug-invariants
#                               (the whole suite again with the solver/
#                                cache invariant checkers compiled in)
#   4j. cargo clippy -D warnings (skipped when clippy is not installed)
#   4k. cargo +nightly miri test on the unsafe-heavy kernel modules
#                               (skipped when miri is not installed)
#   5. cargo build --features pjrt
#                               (the gated runtime module must keep
#                                compiling against the vendor/xla stub)
#   6. cargo test -q --features pjrt
#                               (runtime unit tests + the pjrt smoke test)
set -euo pipefail
cd "$(dirname "$0")/rust"

step() { printf '\n==== %s ====\n' "$*"; }

if cargo fmt --version >/dev/null 2>&1; then
    step "cargo fmt --check"
    cargo fmt --check
else
    step "cargo fmt --check (SKIPPED: rustfmt not installed)"
fi

step "cargo build --release"
cargo build --release

step "cargo test -q"
cargo test -q

# The SIMD wall: the whole suite under the forced-scalar tile, and —
# when the CPU has AVX2 — again under the forced-SIMD tile. The two
# tiles are bit-identical by construction (DESIGN.md §4g), so every
# test must pass under either selection.
step "cargo test -q (PASMO_SIMD=off)"
PASMO_SIMD=off cargo test -q

if grep -q avx2 /proc/cpuinfo 2>/dev/null; then
    step "cargo test -q (PASMO_SIMD=force)"
    PASMO_SIMD=force cargo test -q
else
    step "cargo test -q PASMO_SIMD=force (SKIPPED: no AVX2 on this host)"
fi

step "cargo build --no-default-features"
cargo build --no-default-features

step "cargo build --benches"
cargo build --benches

step "cargo build --release --examples"
cargo build --release --examples

step "cargo run --release --example quickstart -- --len 200"
cargo run --release --example quickstart -- --len 200

# Perf baseline artifact: tiny-scale solver bench (wall time, iterations,
# kernel-entry counts, cache hit rates; shrink on vs off) written to the
# repo root so successive PRs have a trajectory to compare against.
step "pasmo bench --len 300 (writes ../BENCH_solver.json)"
cargo run --release -- bench --len 300 --cache-rows 32 --shrink-interval 50 --out ../BENCH_solver.json

# Inference baseline artifact: tiny-scale batch-scoring bench (queries/s
# and kernel entries for scalar vs tiled vs threaded vs linear-collapse).
step "pasmo bench --predict --len 300 (writes ../BENCH_predict.json)"
cargo run --release -- bench --predict --len 300 --out ../BENCH_predict.json

# Perf trajectory gate: measure the tiny fixed train+predict workload
# (medians of 5 reps) and compare against the committed baseline —
# deterministic counters at ±2%, wall metrics at ±50%. An empty
# committed metric map (how this file is seeded) bootstraps: the run
# measures, saves, and passes, so the first PR on a new host class
# establishes the trajectory the next one is gated against.
step "pasmo bench --check-baseline (gates against ../BENCH_baseline.json)"
cargo run --release -- bench --check-baseline --baseline ../BENCH_baseline.json --len 240

# Sparse substrate gate: the density sweep (the binary enforces the
# CSR-beats-dense resident-bytes claim itself via its internal check),
# then a train → predict round trip over a genuinely sparse LIBSVM file
# through both the streaming and mapped readers.
step "pasmo bench --sparse --len 60 --dim 500 (writes ../BENCH_sparse.json)"
cargo run --release -- bench --sparse --len 60 --dim 500 --out ../BENCH_sparse.json

step "sparse train -> predict round trip (--storage sparse / --mmap)"
SPARSE_DIR=$(mktemp -d)
# A deterministic 2-of-400 density LIBSVM file, no interpreter required:
# two stored entries per row with strictly increasing 1-based indices.
awk 'BEGIN {
    srand(7)
    for (i = 0; i < 120; i++) {
        label = (rand() < 0.5) ? "+1" : "-1"
        a = int(rand() * 200) + 1
        b = a + int(rand() * 199) + 1
        printf "%s %d:%.3f %d:%.3f\n", label, a, rand() * 2 - 1, b, rand() * 2 - 1
    }
}' > "$SPARSE_DIR/train.libsvm"
cargo run --release --quiet -- train --libsvm "$SPARSE_DIR/train.libsvm" \
    --storage sparse --out "$SPARSE_DIR/model.json" >/dev/null
cargo run --release --quiet -- predict --model "$SPARSE_DIR/model.json" \
    --libsvm "$SPARSE_DIR/train.libsvm" --storage sparse --mmap \
    --out "$SPARSE_DIR/preds-sparse.txt" >/dev/null
cargo run --release --quiet -- predict --model "$SPARSE_DIR/model.json" \
    --libsvm "$SPARSE_DIR/train.libsvm" --storage dense \
    --out "$SPARSE_DIR/preds-dense.txt" >/dev/null
cmp "$SPARSE_DIR/preds-sparse.txt" "$SPARSE_DIR/preds-dense.txt" \
    || { echo "sparse gate: CSR and dense decisions diverge"; exit 1; }
rm -rf "$SPARSE_DIR"

# Serving-tier smoke: a real `pasmo serve` process on an ephemeral port
# answers a score line, reports the request in its stats, and drains on
# shutdown with exit 0. Uses bash's /dev/tcp so no netcat is required.
step "pasmo serve smoke (score + stats + shutdown over /dev/tcp)"
SERVE_DIR=$(mktemp -d)
trap 'rm -rf "$SERVE_DIR"' EXIT
cargo run --release --quiet -- train --dataset chess-board-1000 --len 200 \
    --out "$SERVE_DIR/model.json" >/dev/null
cargo run --release --quiet -- serve --model "smoke=$SERVE_DIR/model.json" \
    --addr 127.0.0.1:0 >"$SERVE_DIR/serve.log" &
SERVE_PID=$!
SERVE_ADDR=""
for _ in $(seq 1 100); do
    SERVE_ADDR=$(sed -n 's/.*listening on \([0-9.:]*\).*/\1/p' "$SERVE_DIR/serve.log")
    [ -n "$SERVE_ADDR" ] && break
    kill -0 "$SERVE_PID" 2>/dev/null || { cat "$SERVE_DIR/serve.log"; exit 1; }
    sleep 0.1
done
[ -n "$SERVE_ADDR" ] || { echo "serve never printed its address"; exit 1; }
SERVE_PORT=${SERVE_ADDR##*:}
serve_req() {
    exec 3<>"/dev/tcp/127.0.0.1/$SERVE_PORT"
    printf '%s\n' "$1" >&3
    head -n 1 <&3
    exec 3<&- 3>&-
}
SCORE=$(serve_req '{"model":"smoke","x":[0.25,-0.75],"id":1}')
echo "score reply: $SCORE"
echo "$SCORE" | grep -q '"ok":true' || { echo "serve smoke: score failed"; exit 1; }
STATS=$(serve_req '{"cmd":"stats"}')
echo "$STATS" | grep -q '"requests":1' || { echo "serve smoke: stats missed the request"; exit 1; }
serve_req '{"cmd":"shutdown"}' | grep -q '"shutting_down":true' \
    || { echo "serve smoke: shutdown refused"; exit 1; }
wait "$SERVE_PID" || { echo "serve smoke: nonzero exit"; exit 1; }

# Overload smoke: a one-slot admission queue behind a long window. A
# pipelined burst of 6 queries admits exactly one; the rest must come
# back as explicit load-shed errors, and the server still shuts down
# cleanly with exit 0 (overload never wedges or kills the process).
step "pasmo serve overload smoke (bounded queue sheds with explicit replies)"
cargo run --release --quiet -- serve --model "smoke=$SERVE_DIR/model.json" \
    --addr 127.0.0.1:0 --max-batch 2 --max-wait-us 200000 --max-queue 1 \
    >"$SERVE_DIR/overload.log" &
SERVE_PID=$!
SERVE_ADDR=""
for _ in $(seq 1 100); do
    SERVE_ADDR=$(sed -n 's/.*listening on \([0-9.:]*\).*/\1/p' "$SERVE_DIR/overload.log")
    [ -n "$SERVE_ADDR" ] && break
    kill -0 "$SERVE_PID" 2>/dev/null || { cat "$SERVE_DIR/overload.log"; exit 1; }
    sleep 0.1
done
[ -n "$SERVE_ADDR" ] || { echo "overload smoke: no address"; exit 1; }
SERVE_PORT=${SERVE_ADDR##*:}
exec 3<>"/dev/tcp/127.0.0.1/$SERVE_PORT"
for i in $(seq 1 6); do
    printf '{"model":"smoke","x":[0.25,-0.75],"id":%s}\n' "$i" >&3
done
BURST=$(head -n 6 <&3)
exec 3<&- 3>&-
echo "$BURST" | grep -q '"ok":true' || { echo "overload smoke: nothing scored"; exit 1; }
SHED=$(echo "$BURST" | grep -c 'queue is full' || true)
[ "$SHED" -eq 5 ] || { echo "overload smoke: expected 5 shed replies, got $SHED"; echo "$BURST"; exit 1; }
serve_req '{"cmd":"shutdown"}' | grep -q '"shutting_down":true' \
    || { echo "overload smoke: shutdown refused"; exit 1; }
wait "$SERVE_PID" || { echo "overload smoke: nonzero exit"; exit 1; }

# Serving saturation artifact: the micro-batching sweep at tiny scale.
step "pasmo bench --serve (writes ../BENCH_serve.json)"
cargo run --release -- bench --serve --len 200 --rate 1000 --queries 400 \
    --conns 2 --batches 1,8,64 --out ../BENCH_serve.json

# Chaos gate: the fault-injection hooks armed, the chaos suite green.
# Covers flood → shed (established connections intact), injected scoring
# panic → model quarantine + hot-reload recovery, injected write faults
# (previous artifact survives bit-for-bit), corrupt-checkpoint refusal,
# kill-at-iteration-N + resume to the uninterrupted objective, and
# registry hot-swap under concurrent load.
step "cargo test -q --features fault-injection --test chaos"
cargo test -q --features fault-injection --test chaos

# Docs gate: the public surface is fully documented (#![warn(missing_docs)]
# promoted to an error here) and every doctest runs green.
step "cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

step "cargo test --doc"
cargo test -q --doc

# The three-way engine comparison stays runnable end to end.
step "pasmo experiment engine_shootout (tiny scale)"
cargo run --release -- experiment engine_shootout --datasets thyroid --perms 3 --max-len 150

# Source-tree lint: the binary audits its own src/ against audit.allow.
# Any unlisted panic path, undocumented unsafe, float ==, stray thread,
# print, or HashMap iteration — or a stale allowlist entry — fails CI.
step "pasmo audit"
cargo run --release --quiet -- audit

# Run the whole suite again with the invariant checkers compiled in:
# every solve in every test now validates Σα preservation, box bounds,
# perm/pos bijections, cache byte accounting and gradient parity at the
# shrink/unshrink seams.
step "cargo test -q --features debug-invariants"
cargo test -q --features debug-invariants

# Static analysis and UB detection are best-effort: the offline image may
# not ship clippy or miri, and the gate must not rot when they're absent.
if cargo clippy --version >/dev/null 2>&1; then
    step "cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    step "cargo clippy (SKIPPED: clippy not installed)"
fi

if cargo +nightly miri --version >/dev/null 2>&1; then
    # Scope miri to the unsafe-heavy kernel layer: full-suite miri is
    # orders of magnitude too slow for a CI gate. The AVX2 tile is
    # cfg(not(miri))-gated (vendor intrinsics are unsupported there),
    # so miri exercises the scalar tile through the same kernel::
    # tests — the bit-identity wall makes that coverage transfer.
    step "cargo +nightly miri test kernel::"
    cargo +nightly miri test kernel::
else
    step "cargo miri (SKIPPED: miri not installed)"
fi

step "cargo build --benches --features pjrt"
cargo build --benches --features pjrt

step "cargo build --examples --features pjrt"
cargo build --examples --features pjrt

step "cargo build --features pjrt"
cargo build --features pjrt

step "cargo test -q --features pjrt"
cargo test -q --features pjrt

step "OK"
