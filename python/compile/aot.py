"""AOT compile path: lower the L2 graph to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per entry in ARTIFACTS plus ``MANIFEST.json``
describing shapes and argument order, which the Rust runtime reads to pick
the right artifact for a dataset (smallest D >= d, etc.).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def _gram_artifact(q: int, l: int, d: int):
    """gram_rows entry point at fixed [Q, L, D]; args (xq, x, gamma)."""
    return {
        "entry": "gram_rows",
        "fn": model.gram_rows,
        "args": [_spec(q, d), _spec(l, d), _spec(1, 1)],
        "arg_names": ["xq", "x", "gamma"],
        "out_shape": [q, l],
        "q": q,
        "l": l,
        "d": d,
    }


def _decision_artifact(q: int, l: int, d: int):
    """decision_function at fixed [Q, L, D]; args (xq, x, coef, bias, gamma)."""
    return {
        "entry": "decision_function",
        "fn": model.decision_function,
        "args": [_spec(q, d), _spec(l, d), _spec(l), _spec(1), _spec(1, 1)],
        "arg_names": ["xq", "x", "coef", "bias", "gamma"],
        "out_shape": [q],
        "q": q,
        "l": l,
        "d": d,
    }


# The artifact set the Rust runtime expects. L tiles are chunked by the
# caller, so a single L per entry point suffices; D variants cover the
# suite's feature counts (zero-padding D is exact for RBF).
ARTIFACTS = {
    "gram_q4_l2048_d64": _gram_artifact(4, 2048, 64),
    "gram_q4_l2048_d256": _gram_artifact(4, 2048, 256),
    "gram_q16_l2048_d64": _gram_artifact(16, 2048, 64),
    "decision_q16_l2048_d64": _decision_artifact(16, 2048, 64),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "return_tuple": True, "artifacts": {}}
    for name, art in ARTIFACTS.items():
        lowered = jax.jit(art["fn"]).lower(*art["args"])
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "entry": art["entry"],
            "file": f"{name}.hlo.txt",
            "arg_names": art["arg_names"],
            "arg_shapes": [list(s.shape) for s in art["args"]],
            "out_shape": art["out_shape"],
            "q": art["q"],
            "l": art["l"],
            "d": art["d"],
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(out_dir, 'MANIFEST.json')}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
