"""L2 — JAX compute graph for the PA-SMO system.

For an SVM-training QP solver the "model" is the kernel-computation graph:
the solver's per-iteration hot spot is evaluating Gram rows, and prediction
is a Gram block contracted with the dual coefficients. Both are expressed
here on top of the L1 Pallas kernel so they lower into a single fused HLO
module per entry point (see aot.py).

These functions are build-time only; the Rust runtime executes their AOT
artifacts. Python is never on the request path.
"""

from __future__ import annotations

from .kernels.decision import rbf_decision
from .kernels.rbf_gram import rbf_gram_block


def gram_rows(xq, x, gamma):
    """Gram rows for a block of query points: ``[Q, L]``.

    This is what the SMO hot loop asks for: the kernel rows of the current
    working-set indices (Q=4 artifact) or a batch for warm-up / gradient
    reconstruction after unshrinking (Q=16 artifact).
    """
    return (rbf_gram_block(xq, x, gamma),)


def decision_function(xq, x, coef, bias, gamma):
    """SVM decision values for a query block: ``f(xq) = K(xq, X) coef + b``.

    ``coef`` carries the signed dual variables (alpha in the paper's
    self-dual convention already includes the label sign); padded tail rows
    of ``x`` must come with ``coef = 0`` so they drop out exactly.

    Uses the *fused* L1 kernel (kernels/decision.py): the Gram tile is
    contracted with the coefficient tile inside VMEM, never materializing
    the [Q, L] block in HBM.
    """
    scores = rbf_decision(xq, x, coef.reshape(-1), bias.reshape(1), gamma)
    return (scores,)
