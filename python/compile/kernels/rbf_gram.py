"""L1 — Pallas kernel: tiled RBF Gram block.

Computes ``K[q, l] = exp(-gamma * ||xq[q] - x[l]||^2)`` for a query block
``xq`` of shape ``[Q, D]`` against a data block ``x`` of shape ``[L, D]``.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the squared distance is
decomposed as ``||a||^2 + ||b||^2 - 2 a.b`` so the dominant cost is the
``[Q, D] x [D, TL]`` inner-product block, which lands on the MXU systolic
array. The grid tiles the data dimension L into TL-row tiles; each grid
step streams one ``[TL, D]`` tile of the dataset HBM->VMEM (expressed via
BlockSpec), while the query block and its norms stay resident in VMEM.

VMEM footprint per grid step at the AOT default (Q=16, TL=256, D=64):
    xq 16*64*4 + x 256*64*4 + out 16*256*4  ~= 86 KiB  << 16 MiB VMEM.

``interpret=True`` is mandatory in this environment: real-TPU lowering
emits a Mosaic custom-call that the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Data-dimension tile. 256 rows keeps the MXU-bound matmul fat while the
# per-step VMEM footprint stays tiny; it also divides every AOT L choice.
DEFAULT_TILE_L = 256


def _rbf_block_kernel(gamma_ref, xq_ref, x_ref, o_ref):
    """One grid step: RBF Gram block of the query block vs one data tile."""
    xq = xq_ref[...]  # [Q, D], VMEM-resident across the grid
    x = x_ref[...]  # [TL, D], streamed tile
    gamma = gamma_ref[0, 0]
    # ||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b ; the a.b term is the MXU matmul.
    qn = jnp.sum(xq * xq, axis=1, keepdims=True)  # [Q, 1]
    xn = jnp.sum(x * x, axis=1, keepdims=True)  # [TL, 1]
    cross = jax.lax.dot_general(
        xq,
        x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q, TL]
    d2 = qn + xn.T - 2.0 * cross
    # Zero-clamp: padding and cancellation can push d2 epsilon-negative.
    o_ref[...] = jnp.exp(-gamma * jnp.maximum(d2, 0.0))


@functools.partial(jax.jit, static_argnames=("tile_l",))
def rbf_gram_block(xq, x, gamma, *, tile_l: int = DEFAULT_TILE_L):
    """RBF Gram block ``[Q, L]`` of ``xq`` [Q, D] vs ``x`` [L, D].

    ``gamma`` is a runtime scalar (shape ``[1, 1]`` f32) so one AOT artifact
    serves every dataset. ``L`` must be a multiple of ``tile_l``; the Rust
    caller zero-pads D (exact for RBF) and masks the padded L tail.
    """
    q, d = xq.shape
    l, d2 = x.shape
    if d != d2:
        raise ValueError(f"feature dims differ: xq has {d}, x has {d2}")
    tile_l = min(tile_l, l)
    if l % tile_l != 0:
        raise ValueError(f"L={l} not a multiple of tile_l={tile_l}")
    gamma = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    grid = (l // tile_l,)
    return pl.pallas_call(
        _rbf_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # gamma, replicated
            pl.BlockSpec((q, d), lambda i: (0, 0)),  # query block, resident
            pl.BlockSpec((tile_l, d), lambda i: (i, 0)),  # streamed data tile
        ],
        out_specs=pl.BlockSpec((q, tile_l), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, l), jnp.float32),
        interpret=True,  # CPU-PJRT executable; real TPU would drop this
    )(gamma, xq.astype(jnp.float32), x.astype(jnp.float32))
