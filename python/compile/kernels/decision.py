"""L1 — Pallas kernel: fused RBF decision function.

Computes ``f[q] = Σ_l coef[l] · exp(-gamma ||xq[q] - x[l]||²) + bias`` in a
single kernel: each grid step forms one ``[Q, TL]`` Gram tile (MXU matmul
for the cross term, exactly as in rbf_gram.py) and immediately contracts
it with the coefficient tile — the ``[Q, L]`` Gram block is never
materialized in HBM. The output block maps every grid step to the same
``[Q]`` accumulator (TPU grid steps are sequential, so `+=` is sound; this
is the canonical Pallas reduction idiom).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .rbf_gram import DEFAULT_TILE_L


def _decision_kernel(gamma_ref, xq_ref, x_ref, coef_ref, bias_ref, o_ref):
    xq = xq_ref[...]  # [Q, D]
    x = x_ref[...]  # [TL, D]
    coef = coef_ref[...]  # [TL]
    gamma = gamma_ref[0, 0]
    qn = jnp.sum(xq * xq, axis=1, keepdims=True)  # [Q, 1]
    xn = jnp.sum(x * x, axis=1, keepdims=True)  # [TL, 1]
    cross = jax.lax.dot_general(
        xq,
        x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q, TL]
    k = jnp.exp(-gamma * jnp.maximum(qn + xn.T - 2.0 * cross, 0.0))
    contrib = k @ coef  # [Q] — fused contraction, Gram tile stays in VMEM

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref) + bias_ref[0]

    @pl.when(pl.program_id(0) != 0)
    def _acc():
        o_ref[...] += contrib

    # program 0 must also add its contribution after initializing
    @pl.when(pl.program_id(0) == 0)
    def _first():
        o_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("tile_l",))
def rbf_decision(xq, x, coef, bias, gamma, *, tile_l: int = DEFAULT_TILE_L):
    """Fused decision values ``[Q]`` for queries ``xq`` against SVs ``x``.

    ``coef`` carries the signed dual coefficients (length L, zero on
    padded rows); ``bias`` is shape ``[1]``; ``gamma`` a runtime scalar.
    """
    q, d = xq.shape
    l, d2 = x.shape
    if d != d2:
        raise ValueError(f"feature dims differ: xq has {d}, x has {d2}")
    if coef.shape != (l,):
        raise ValueError(f"coef shape {coef.shape} != ({l},)")
    tile_l = min(tile_l, l)
    if l % tile_l != 0:
        raise ValueError(f"L={l} not a multiple of tile_l={tile_l}")
    gamma = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    grid = (l // tile_l,)
    return pl.pallas_call(
        _decision_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # gamma
            pl.BlockSpec((q, d), lambda i: (0, 0)),  # queries, resident
            pl.BlockSpec((tile_l, d), lambda i: (i, 0)),  # SV tile
            pl.BlockSpec((tile_l,), lambda i: (i,)),  # coef tile
            pl.BlockSpec((1,), lambda i: (0,)),  # bias
        ],
        out_specs=pl.BlockSpec((q,), lambda i: (0,)),  # shared accumulator
        out_shape=jax.ShapeDtypeStruct((q,), jnp.float32),
        interpret=True,
    )(
        gamma,
        xq.astype(jnp.float32),
        x.astype(jnp.float32),
        jnp.asarray(coef, jnp.float32),
        jnp.asarray(bias, jnp.float32).reshape(1),
    )
