"""Pure-jnp oracle for the Pallas kernels (the CORE correctness signal).

Everything here is written in the most direct way possible — broadcasted
squared distances, no tiling, no tricks — so that a mismatch against the
Pallas kernel unambiguously blames the kernel.
"""

from __future__ import annotations

import jax.numpy as jnp


def rbf_gram_block_ref(xq, x, gamma):
    """Reference RBF Gram block: ``K[q, l] = exp(-gamma ||xq[q]-x[l]||^2)``."""
    xq = jnp.asarray(xq, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    diff = xq[:, None, :] - x[None, :, :]  # [Q, L, D]
    d2 = jnp.sum(diff * diff, axis=-1)  # [Q, L]
    return jnp.exp(-jnp.float32(gamma) * d2)


def decision_function_ref(xq, x, coef, bias, gamma):
    """Reference SVM decision values: ``f(xq) = K(xq, x) @ coef + bias``."""
    k = rbf_gram_block_ref(xq, x, gamma)
    return k @ jnp.asarray(coef, jnp.float32) + jnp.float32(bias)
