"""Make `compile.*` importable whether pytest runs from repo root
(`pytest python/tests/`) or from `python/` (`pytest tests/`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
