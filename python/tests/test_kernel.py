"""L1 correctness: Pallas RBF Gram kernel vs the pure-jnp oracle.

This is the core correctness signal for the compute layer: hypothesis
sweeps shapes, gammas and value ranges, asserting allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import assume, given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.ref import rbf_gram_block_ref
from compile.kernels.rbf_gram import rbf_gram_block


def _mk(rng, q, l, d, scale):
    xq = rng.normal(size=(q, d)).astype(np.float32) * scale
    x = rng.normal(size=(l, d)).astype(np.float32) * scale
    return xq, x


def test_identity_diagonal():
    """k(x, x) == 1 exactly for any gamma."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, 5)).astype(np.float32)
    k = np.asarray(rbf_gram_block(x, x, 3.7, tile_l=8))
    assert_allclose(np.diag(k), np.ones(8), rtol=0, atol=1e-6)


def test_matches_ref_basic():
    rng = np.random.default_rng(1)
    xq, x = _mk(rng, 4, 512, 16, 1.0)
    got = np.asarray(rbf_gram_block(xq, x, 0.5))
    want = np.asarray(rbf_gram_block_ref(xq, x, 0.5))
    assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_values_in_unit_interval():
    rng = np.random.default_rng(2)
    xq, x = _mk(rng, 3, 256, 8, 10.0)
    k = np.asarray(rbf_gram_block(xq, x, 2.0))
    assert np.all(k >= 0.0) and np.all(k <= 1.0 + 1e-6)


def test_gamma_zero_gives_ones():
    rng = np.random.default_rng(3)
    xq, x = _mk(rng, 2, 256, 4, 1.0)
    k = np.asarray(rbf_gram_block(xq, x, 0.0))
    assert_allclose(k, np.ones_like(k), rtol=0, atol=1e-7)


def test_feature_zero_padding_is_exact():
    """Zero-padding D must not change the Gram block (RBF property the
    Rust runtime relies on when padding datasets to the artifact D)."""
    rng = np.random.default_rng(4)
    xq, x = _mk(rng, 4, 256, 10, 1.0)
    k0 = np.asarray(rbf_gram_block(xq, x, 0.7))
    pad = lambda a, d: np.pad(a, ((0, 0), (0, d - a.shape[1])))
    k1 = np.asarray(rbf_gram_block(pad(xq, 64), pad(x, 64), 0.7))
    assert_allclose(k0, k1, rtol=0, atol=1e-6)


def test_mismatched_feature_dims_raise():
    with pytest.raises(ValueError, match="feature dims differ"):
        rbf_gram_block(np.zeros((2, 3), np.float32), np.zeros((4, 5), np.float32), 1.0)


def test_non_divisible_tile_raises():
    with pytest.raises(ValueError, match="not a multiple"):
        rbf_gram_block(
            np.zeros((2, 4), np.float32), np.zeros((300, 4), np.float32), 1.0
        )


def test_float64_inputs_are_cast():
    rng = np.random.default_rng(5)
    xq = rng.normal(size=(2, 4))
    x = rng.normal(size=(256, 4))
    k = rbf_gram_block(xq, x, 1.0)
    assert k.dtype == jnp.float32


@settings(max_examples=40, deadline=None)
@given(
    q=st.integers(1, 16),
    l_tiles=st.integers(1, 4),
    tile=st.sampled_from([8, 32, 128]),
    d=st.integers(1, 48),
    gamma=st.floats(1e-4, 50.0),
    scale=st.floats(0.01, 20.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_hypothesis(q, l_tiles, tile, d, gamma, scale, seed):
    """Shape/parameter sweep: Pallas == reference to f32 tolerance.

    The ||a||²+||b||²−2ab decomposition has an irreducible f32 error of
    ~eps·||x||² in d², i.e. ~γ·eps·||x||² relative error in exp(−γd²);
    beyond γ·scale² ≈ 400 that exceeds any meaningful tolerance, so the
    sweep stays inside the numerically faithful regime (the solver's
    γ·||x||² is far below this for every suite dataset).
    """
    assume(gamma * scale * scale <= 400.0)
    rng = np.random.default_rng(seed)
    l = l_tiles * tile
    xq, x = _mk(rng, q, l, d, scale)
    got = np.asarray(rbf_gram_block(xq, x, gamma, tile_l=tile))
    want = np.asarray(rbf_gram_block_ref(xq, x, gamma))
    assert got.shape == (q, l)
    # f32 tolerance: the kernel uses the MXU-friendly ||a||^2+||b||^2-2ab
    # decomposition, which loses a few ulp to cancellation at large scales
    # relative to the direct-difference oracle.
    assert_allclose(got, want, rtol=1e-3, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    tile=st.sampled_from([16, 64]),
    d=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_duplicate_points_give_one(tile, d, seed):
    """If a query equals a data point, that Gram entry is exactly ~1."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(tile, d)).astype(np.float32)
    xq = x[:4].copy() if tile >= 4 else x[:1].copy()
    k = np.asarray(rbf_gram_block(xq, x, 1.3, tile_l=tile))
    for i in range(xq.shape[0]):
        assert abs(k[i, i] - 1.0) < 1e-5
