"""Fused decision kernel vs the pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.decision import rbf_decision
from compile.kernels.ref import decision_function_ref


def _data(seed, q, l, d):
    rng = np.random.default_rng(seed)
    xq = rng.normal(size=(q, d)).astype(np.float32)
    x = rng.normal(size=(l, d)).astype(np.float32)
    coef = rng.normal(size=(l,)).astype(np.float32)
    return xq, x, coef


def test_matches_ref_basic():
    xq, x, coef = _data(0, 8, 512, 16)
    got = np.asarray(rbf_decision(xq, x, coef, np.float32(0.75), 0.5))
    want = np.asarray(decision_function_ref(xq, x, coef, 0.75, 0.5))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_multi_tile_accumulation_is_exact():
    """The cross-tile accumulator must agree with the single-tile result."""
    xq, x, coef = _data(1, 4, 512, 8)
    one_tile = np.asarray(rbf_decision(xq, x, coef, np.float32(0.0), 1.0, tile_l=512))
    many_tiles = np.asarray(rbf_decision(xq, x, coef, np.float32(0.0), 1.0, tile_l=64))
    assert_allclose(one_tile, many_tiles, rtol=1e-5, atol=1e-5)


def test_zero_coef_gives_bias():
    xq, x, _ = _data(2, 3, 256, 4)
    got = np.asarray(rbf_decision(xq, x, np.zeros(256, np.float32), np.float32(2.5), 1.0))
    assert_allclose(got, np.full(3, 2.5, np.float32), rtol=0, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    q=st.integers(1, 12),
    tiles=st.integers(1, 4),
    tile=st.sampled_from([32, 128]),
    d=st.integers(1, 24),
    gamma=st.floats(1e-3, 5.0),
    bias=st.floats(-3.0, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_hypothesis(q, tiles, tile, d, gamma, bias, seed):
    l = tiles * tile
    xq, x, coef = _data(seed, q, l, d)
    got = np.asarray(rbf_decision(xq, x, coef, np.float32(bias), gamma, tile_l=tile))
    want = np.asarray(decision_function_ref(xq, x, coef, bias, gamma))
    assert got.shape == (q,)
    assert_allclose(got, want, rtol=1e-3, atol=2e-3)
