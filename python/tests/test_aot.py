"""AOT path: artifacts are emitted, manifest is consistent, and the HLO
text round-trips through the XLA client with correct numerics (the same
load path the Rust runtime uses, minus the C API)."""

import json
import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot
from compile.kernels.ref import rbf_gram_block_ref


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out))
    return str(out), manifest


def test_manifest_lists_all_artifacts(built):
    out, manifest = built
    assert set(manifest["artifacts"]) == set(aot.ARTIFACTS)
    for name, meta in manifest["artifacts"].items():
        path = os.path.join(out, meta["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 100, name
        assert len(meta["arg_names"]) == len(meta["arg_shapes"])


def test_manifest_round_trips_as_json(built):
    out, manifest = built
    with open(os.path.join(out, "MANIFEST.json")) as f:
        loaded = json.load(f)
    assert loaded == manifest
    assert loaded["format"] == "hlo-text"
    assert loaded["return_tuple"] is True


def test_gram_artifact_shapes_match_names(built):
    _, manifest = built
    meta = manifest["artifacts"]["gram_q4_l2048_d64"]
    assert meta["arg_shapes"] == [[4, 64], [2048, 64], [1, 1]]
    assert meta["out_shape"] == [4, 2048]


def test_hlo_text_parses(built):
    """Every emitted HLO text must parse back through the XLA text parser —
    the exact entry gate of the Rust runtime's `HloModuleProto::from_text_file`.
    (Numeric round-trip through the C API is covered by the Rust
    integration test `runtime::tests` / examples/quickstart.)"""
    out, manifest = built
    from jax._src.lib import xla_client as xc

    for name, meta in manifest["artifacts"].items():
        with open(os.path.join(out, meta["file"])) as f:
            text = f.read()
        assert "ENTRY" in text, name
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None, name


def test_gram_lowering_numerics_vs_ref(built):
    """The lowered-and-jitted artifact function (the exact computation the
    HLO text encodes) matches the oracle at the AOT shapes."""
    from compile import model

    rng = np.random.default_rng(0)
    xq = rng.normal(size=(4, 64)).astype(np.float32)
    x = rng.normal(size=(2048, 64)).astype(np.float32)
    (got,) = model.gram_rows(xq, x, np.float32(0.5))
    assert_allclose(
        np.asarray(got), rbf_gram_block_ref(xq, x, 0.5), rtol=1e-4, atol=1e-6
    )
