"""L2 correctness: model entry points vs reference, shapes, padding rules."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels.ref import decision_function_ref, rbf_gram_block_ref


def _data(seed, q, l, d):
    rng = np.random.default_rng(seed)
    xq = rng.normal(size=(q, d)).astype(np.float32)
    x = rng.normal(size=(l, d)).astype(np.float32)
    coef = rng.normal(size=(l,)).astype(np.float32)
    return xq, x, coef


def test_gram_rows_matches_ref():
    xq, x, _ = _data(0, 4, 512, 16)
    (k,) = model.gram_rows(xq, x, np.float32(0.25))
    assert_allclose(np.asarray(k), rbf_gram_block_ref(xq, x, 0.25), rtol=1e-5, atol=1e-6)


def test_decision_matches_ref():
    xq, x, coef = _data(1, 16, 512, 16)
    bias = np.asarray([0.375], np.float32)
    (scores,) = model.decision_function(xq, x, coef, bias, np.float32(0.1))
    want = decision_function_ref(xq, x, coef, 0.375, 0.1)
    assert_allclose(np.asarray(scores), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_decision_padded_tail_drops_out():
    """Padded data rows with coef=0 must not change decision values —
    the contract the Rust runtime relies on when chunking L."""
    xq, x, coef = _data(2, 8, 256, 8)
    bias = np.asarray([0.0], np.float32)
    (s0,) = model.decision_function(xq, x, coef, bias, np.float32(0.5))
    xpad = np.vstack([x, np.full((256, 8), 7.5, np.float32)])
    cpad = np.concatenate([coef, np.zeros(256, np.float32)])
    (s1,) = model.decision_function(xq, xpad, cpad, bias, np.float32(0.5))
    assert_allclose(np.asarray(s0), np.asarray(s1), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    q=st.integers(1, 16),
    l=st.sampled_from([256, 512]),
    d=st.integers(1, 32),
    gamma=st.floats(1e-3, 10.0),
    bias=st.floats(-5.0, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_decision_hypothesis(q, l, d, gamma, bias, seed):
    xq, x, coef = _data(seed, q, l, d)
    b = np.asarray([bias], np.float32)
    (scores,) = model.decision_function(xq, x, coef, b, np.float32(gamma))
    want = decision_function_ref(xq, x, coef, bias, gamma)
    assert_allclose(np.asarray(scores), np.asarray(want), rtol=5e-4, atol=1e-4)
